"""Render EXPERIMENTS.md tables from results/*.jsonl.

  python -m repro.launch.report_md [--dryrun results/dryrun.jsonl]
                                   [--hillclimb results/hillclimb.jsonl]
"""
import argparse
import json
from collections import OrderedDict


def _load(path):
    try:
        with open(path) as f:
            return [json.loads(l) for l in f if l.strip()]
    except FileNotFoundError:
        return []


def _ms(x):
    return f"{x*1e3:.2f}"


def roofline_table(recs, mesh="single"):
    rows = ["| arch | shape | compute (ms) | memory (ms) | collective (ms) |"
            " bound | MODEL/HLO | roofline frac | fits ≤16 GiB |",
            "|---|---|---:|---:|---:|---|---:|---:|---|"]
    # keep the latest record per cell
    latest = OrderedDict()
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        latest[(r["arch"], r["shape"])] = r
    for (arch, shape), r in latest.items():
        if r["status"] == "skip":
            rows.append(f"| {arch} | {shape} | — | — | — | SKIP | — | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {arch} | {shape} | — | — | — | ERROR | — | — | — |")
            continue
        rr = r["roofline"]
        m = r.get("memory", {})
        per_dev = (m.get("argument_size_in_bytes", 0)
                   + m.get("temp_size_in_bytes", 0)
                   + m.get("output_size_in_bytes", 0)) / 2**30
        fits = "yes" if per_dev <= 16 else f"no ({per_dev:.0f} GiB)"
        rows.append(
            f"| {arch} | {shape} | {_ms(rr['compute_s'])} "
            f"| {_ms(rr['memory_s'])} | {_ms(rr['collective_s'])} "
            f"| {rr['bottleneck']} | {rr['model_flops_ratio']:.3f} "
            f"| {rr['roofline_fraction']:.4f} | {fits} |")
    return "\n".join(rows)


def collective_table(recs, mesh="multi"):
    rows = ["| arch | shape | ICI bytes/device | top collectives |",
            "|---|---|---:|---|"]
    latest = OrderedDict()
    for r in recs:
        if r.get("mesh") != mesh or r["status"] != "ok":
            continue
        latest[(r["arch"], r["shape"])] = r
    for (arch, shape), r in latest.items():
        cols = r["stream"].get("collectives", {})
        tops = ", ".join(f"{k}={v/2**20:.0f} MiB" for k, v in
                         sorted(cols.items(), key=lambda kv: -kv[1])[:3])
        rows.append(f"| {arch} | {shape} "
                    f"| {r['roofline']['ici_bytes_per_device']/2**30:.2f} GiB "
                    f"| {tops} |")
    return "\n".join(rows)


def trace_table(recs, mesh="single"):
    """Unified-session event accounting per cell (from TraceSession.summary)."""
    rows = ["| arch | shape | events | compile | dispatch | transfer "
            "| graph_launch | progress | host dispatch (ms) |",
            "|---|---|---:|---:|---:|---:|---:|---:|---:|"]
    latest = OrderedDict()
    for r in recs:
        if r.get("mesh") != mesh or r.get("status") != "ok":
            continue
        if "trace" in r:
            latest[(r["arch"], r["shape"])] = r
    for (arch, shape), r in latest.items():
        t = r["trace"]
        k = t.get("by_kind", {})
        rows.append(
            f"| {arch} | {shape} | {t.get('events', 0)} "
            f"| {k.get('compile', 0)} | {k.get('dispatch', 0)} "
            f"| {k.get('transfer', 0)} | {k.get('graph_launch', 0)} "
            f"| {k.get('progress', 0)} "
            f"| {_ms(t.get('total_dispatch_s', 0.0))} |")
    return "\n".join(rows)


def hillclimb_table(recs):
    rows = ["| label | arch × shape | compute (ms) | memory (ms) "
            "| collective (ms) | bound | roofline frac |",
            "|---|---|---:|---:|---:|---|---:|"]
    for r in recs:
        if r["status"] != "ok":
            continue
        rr = r.get("roofline_kernel_credited") or r["roofline"]
        rows.append(
            f"| {r.get('label','?')} | {r['arch']} × {r['shape']} "
            f"| {_ms(rr['compute_s'])} | {_ms(rr['memory_s'])} "
            f"| {_ms(rr['collective_s'])} | {rr['bottleneck']} "
            f"| {rr['roofline_fraction']:.4f} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun.jsonl")
    ap.add_argument("--hillclimb", default="results/hillclimb.jsonl")
    ap.add_argument("--section", default="all",
                    choices=["all", "roofline", "multi", "trace",
                             "hillclimb"])
    args = ap.parse_args()
    dr = _load(args.dryrun)
    hc = _load(args.hillclimb)
    if args.section in ("all", "roofline"):
        print("### Single-pod (16×16 = 256 chips) baseline roofline\n")
        print(roofline_table(dr, "single"))
    if args.section in ("all", "multi"):
        print("\n### Multi-pod (2×16×16 = 512 chips) collective check\n")
        print(collective_table(dr, "multi"))
    if args.section in ("all", "trace"):
        print("\n### Unified submission-event timeline (TraceSession)\n")
        print(trace_table(dr, "single"))
    if args.section in ("all", "hillclimb"):
        print("\n### Hillclimb iterations\n")
        print(hillclimb_table(hc))


if __name__ == "__main__":
    main()
