"""Perf hillclimbing driver: one (arch x shape x mesh) cell per invocation,
with config overrides, full command-stream breakdown, and optional Pallas
kernel credit.  Appends labeled records to results/hillclimb.jsonl so the
EXPERIMENTS.md SSPerf log can show every hypothesis -> change -> before/after.

  python -m repro.launch.hillclimb --arch llava-next-34b --shape prefill_32k \
      --label sp_on --set seq_shard=True --set attn_chunk=2048

For the generalized, objective-driven search over the exposed submission
knobs (DMA threshold, tokens/steps per launch), see ``python -m repro.tune``
(:mod:`repro.tune.search` is this driver's coordinate-descent descendant).
"""
import os
# Must precede any jax import: jax locks the device count at first init.
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
from typing import Any, Dict

from ..core import adjusted, analyze, attribute
from ..tune.search import parse_spec, parse_value
from .dryrun import run_cell


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--label", required=True)
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=value")
    ap.add_argument("--kernel-credit", action="append", default=[],
                    help=("tag:read_write_bytes_per_device — replace the "
                          "tagged interior's HBM traffic with the kernel's "
                          "I/O working set (Pallas VMEM-resident tiles)"))
    ap.add_argument("--kernel-credit-flops", action="append", default=[],
                    help="tag:flops_scale (e.g. causal skip = 0.5)")
    ap.add_argument("--kernel-credit-mult", default=None,
                    help=("min_multiplier:io_bytes — credit ALL entries with "
                          "execution multiplier >= min (kernel-interior loop "
                          "bodies) down to the kernel I/O working set"))
    ap.add_argument("--pp", action="store_true",
                    help="use the shard_map pipeline-parallel decode path")
    ap.add_argument("--pp-tokens", type=int, default=1,
                    help="tokens scored per PP launch (weight-stream amortization)")
    ap.add_argument("--top", type=int, default=14)
    ap.add_argument("--out", default="results/hillclimb.jsonl")
    args = ap.parse_args()

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        overrides[k] = parse_value(v)

    if args.pp:
        from .dryrun import run_pp_cell
        rec = run_pp_cell(args.arch, args.shape, args.mesh == "multi",
                          overrides=overrides, keep_artifacts=True,
                          tokens_per_launch=args.pp_tokens)
    else:
        rec = run_cell(args.arch, args.shape, args.mesh == "multi",
                       keep_artifacts=True, overrides=overrides)
    if rec["status"] != "ok":
        print(json.dumps({k: v for k, v in rec.items()
                          if not k.startswith("_")}, indent=2)[:2000])
        raise SystemExit(1)
    cs = rec.pop("_captured")
    rep = analyze(cs, chips=rec["chips"],
                  model_flops_total=rec["roofline"]["model_flops_total"])

    # ---- optional kernel credit -------------------------------------------
    credits: Dict[str, Any] = {}
    d_mem = d_flops = 0.0
    # specs split on the LAST colon (tags are op paths that may contain ':')
    for spec in args.kernel_credit:
        tag, io_bytes = parse_spec(spec)
        a = attribute(cs, tag)
        d_mem += float(io_bytes) - a["memory_bytes"]
        credits[tag] = {"replaced_mem": a["memory_bytes"],
                        "with_io_bytes": float(io_bytes)}
    for spec in args.kernel_credit_flops:
        tag, scale = parse_spec(spec)
        a = attribute(cs, tag)
        d_flops += (float(scale) - 1.0) * a["flops"]
        credits.setdefault(tag, {})["flops_scale"] = float(scale)
    if args.kernel_credit_mult:
        min_mult, io_bytes = args.kernel_credit_mult.rsplit(":", 1)
        interior = sum((e.result_bytes + e.operand_bytes) * e.multiplier
                       for e in cs.stream.entries
                       if e.multiplier >= int(min_mult))
        d_mem += float(io_bytes) - interior
        credits["mult>=" + min_mult] = {"replaced_mem": interior,
                                        "with_io_bytes": float(io_bytes)}
    if credits:
        rep = adjusted(rep, d_flops=d_flops, d_mem=d_mem,
                       name=rep.name + "+kernels")
        rec["roofline_kernel_credited"] = rep.to_dict()
        rec["kernel_credits"] = credits

    # ---- breakdowns -------------------------------------------------------
    ent = cs.stream.entries
    print(f"\n===== {args.label}: {args.arch} x {args.shape} x {args.mesh} =====")
    r = rec["roofline_kernel_credited"] if credits else rec["roofline"]
    print(f"compute={r['compute_s']*1e3:.2f}ms memory={r['memory_s']*1e3:.2f}ms "
          f"collective={r['collective_s']*1e3:.2f}ms -> {r['bottleneck']}"
          f"  MFr={r['model_flops_ratio']:.3f} RF={r['roofline_fraction']:.4f}")
    m = rec["memory"]
    print(f"mem/device: args={m.get('argument_size_in_bytes',0)/2**30:.2f} "
          f"temp={m.get('temp_size_in_bytes',0)/2**30:.2f} GiB")
    print(f"attribution: {json.dumps(rec['attribution'])}")
    for metric, key in (("FLOPS", lambda e: e.flops * e.multiplier),
                        ("MEM", lambda e: (e.result_bytes + e.operand_bytes)
                         * e.multiplier),
                        ("ICI", lambda e: e.link_bytes * e.multiplier)):
        top = sorted(ent, key=key, reverse=True)[:args.top]
        tot = sum(key(e) for e in ent) or 1
        print(f"--- top {metric} ---")
        for e in top:
            if key(e) <= 0:
                break
            print(f"  {100*key(e)/tot:5.1f}% {e.opcode:<18s} x{e.multiplier:<5d}"
                  f" {key(e):.3e}  {e.op_path[-90:]}")

    rec["label"] = args.label
    rec["overrides"] = overrides
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "a") as f:
        f.write(json.dumps({k: v for k, v in rec.items()
                            if not k.startswith("_")}) + "\n")


if __name__ == "__main__":
    main()
