"""Serving launcher: batched greedy decode with multi-token launches.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --smoke \
        [--tokens-per-launch 4] [--batch 4] [--new-tokens 16] [--continuous]

``--continuous`` serves the same requests through the continuous-batching
engine (queued admission, per-request KV slots) instead of one static
batch; ``python -m repro.launch.loadtest`` is the full traffic harness.
``--live [PORT]`` (with ``--continuous``) exposes the engine's live
session summary over HTTP while it runs (``GET /summary``,
``GET /stream`` — see :mod:`repro.obs.live`).

``--trace PATH`` writes a fleet-identified JSONL shard of the run
(``host``/``process`` tags, per-process filename) for
``repro.obs.aggregate`` / ``repro.obs.export``; ``--profile`` prints
per-span command attribution (``serve.request``, ``serve.decode_iter``,
``serve.prefill``) after the run.
"""
from __future__ import annotations

import argparse

import numpy as np

from ..configs import ARCHS, SMOKE_ARCHS
from ..runtime.server import ContinuousBatchingServer, Request, Server
from ..tune.policy import load_policy_for
from .mesh import fleet_session


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=list(ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--tokens-per-launch", type=int, default=None,
                    help="unset -> auto-apply the tuned policy "
                         "(python -m repro.tune), else 4")
    ap.add_argument("--continuous", action="store_true",
                    help="serve through the continuous-batching engine")
    ap.add_argument("--kv", default="dense", choices=("dense", "paged"),
                    help="with --continuous: KV-cache backend")
    ap.add_argument("--kv-page-tokens", type=int, default=None,
                    help="paged page size in tokens (unset -> tuned/16)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="max tokens per prefill launch (unset -> "
                         "tuned/off)")
    ap.add_argument("--sched", default="fifo",
                    choices=("fifo", "priority", "fair"),
                    help="with --continuous: admission scheduling policy")
    ap.add_argument("--requests", type=int, default=None,
                    help="request count for --continuous (default: batch)")
    ap.add_argument("--live", type=int, default=None, nargs="?", const=0,
                    metavar="PORT",
                    help="with --continuous: serve the live summary over "
                         "HTTP while the engine runs")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write this process's JSONL trace shard "
                         "(fleet-tagged, per-process filename)")
    ap.add_argument("--profile", action="store_true",
                    help="print per-span command attribution after the run")
    args = ap.parse_args()

    cfg = (SMOKE_ARCHS if args.smoke else ARCHS)[args.arch]
    tpl = args.tokens_per_launch
    if tpl is None and load_policy_for(cfg, activate=False) is None:
        tpl = 4                      # legacy CLI default when untuned
    session, shard = fleet_session("serve", trace_path=args.trace)
    prof = None
    if args.profile:
        from ..obs.profile import SpanProfile
        prof = SpanProfile(name="serve")
        session.add_sink(prof)
    if args.continuous:
        srv = ContinuousBatchingServer(
            cfg, batch_size=args.batch, max_seq=args.max_seq,
            tokens_per_launch=tpl, seed=args.seed, session=session,
            kv=args.kv, kv_page_tokens=args.kv_page_tokens,
            prefill_chunk=args.prefill_chunk, sched=args.sched)
    else:
        srv = Server(cfg, batch_size=args.batch, max_seq=args.max_seq,
                     tokens_per_launch=tpl, seed=args.seed, session=session)
    if srv.policy is not None:
        print(f"policy: {srv.policy.arch} knobs={srv.policy.knobs} "
              f"objective={srv.policy.objective.get('after')}")
    rng = np.random.default_rng(args.seed)
    n = (args.requests or args.batch) if args.continuous else args.batch
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=args.prompt_len).astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for i in range(n)]
    if args.continuous:
        live_srv = None
        if args.live is not None:
            live_srv = srv.start_live_endpoint(port=args.live)
            print(f"live summary endpoint: {live_srv.url}/summary")
        for r in reqs:
            srv.submit(r)
        try:
            out = srv.run()
        finally:
            if live_srv is not None:
                srv.stop_live_endpoint()
    else:
        out = srv.serve(reqs)
    print(out)
    for r in reqs[:2]:
        print(f"req {r.uid}: {r.tokens}")
    print(srv.session.report(max_events=30))
    if prof is not None:
        print(prof.report())
    session.close()
    if shard:
        print(f"trace shard: {shard}")


if __name__ == "__main__":
    main()
