"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets ``xla_force_host_platform_device_count`` before
calling.  Axes:

  (data=16, model=16)            — one v5e pod slice, 256 chips
  (pod=2, data=16, model=16)     — two pods, 512 chips
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh", "SINGLE_POD", "MULTI_POD"]

SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)


def _make(shape, axes):
    # jax < 0.5 has neither sharding.AxisType nor make_mesh(axis_types=...);
    # Auto is that older default, so plain make_mesh is equivalent there.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                shape, axes, axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh(data: int, model: int, pod: int = 1):
    """Arbitrary (pod ×) data × model mesh for tests/examples."""
    if pod > 1:
        return _make((pod, data, model), ("pod", "data", "model"))
    return _make((data, model), ("data", "model"))
