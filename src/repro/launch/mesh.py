"""Production meshes + the fleet-identity session helper.

Meshes are defined as FUNCTIONS so importing this module never touches jax
device state; the dry-run sets ``xla_force_host_platform_device_count``
before calling.  Axes:

  (data=16, model=16)            — one v5e pod slice, 256 chips
  (pod=2, data=16, model=16)     — two pods, 512 chips

:func:`fleet_session` is the one place launchers build their
:class:`~repro.core.session.TraceSession`: it stamps the session with
:func:`~repro.distributed.context.process_tags` (so every event carries
``host``/``process`` — the shard identity :mod:`repro.obs.aggregate`
merges by) and, when a trace path is given, attaches a
:class:`~repro.core.session.JsonlSink` at the per-process
:func:`~repro.distributed.context.shard_path`.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

__all__ = ["make_production_mesh", "make_mesh", "fleet_session",
           "SINGLE_POD", "MULTI_POD"]

SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)


def _make(shape, axes):
    # jax < 0.5 has neither sharding.AxisType nor make_mesh(axis_types=...);
    # Auto is that older default, so plain make_mesh is equivalent there.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        try:
            return jax.make_mesh(
                shape, axes, axis_types=(axis_type.Auto,) * len(axes))
        except TypeError:
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make(shape, axes)


def make_mesh(data: int, model: int, pod: int = 1):
    """Arbitrary (pod ×) data × model mesh for tests/examples."""
    if pod > 1:
        return _make((pod, data, model), ("pod", "data", "model"))
    return _make((data, model), ("data", "model"))


def fleet_session(name: str, trace_path: Optional[str] = None
                  ) -> Tuple["object", Optional[str]]:
    """Build this process's fleet-identified :class:`TraceSession`.

    Returns ``(session, shard_jsonl_path)`` — the path is None without
    ``trace_path``, else the :func:`shard_path`-mangled per-process file
    (``trace.jsonl`` -> ``trace.p3.jsonl`` in a 4-process fleet) ready for
    ``python -m repro.obs.aggregate`` / ``python -m repro.obs.export``.
    """
    from ..core.session import TraceSession
    from ..distributed.context import process_tags, shard_path
    path = shard_path(trace_path) if trace_path else None
    return TraceSession(name=name, jsonl_path=path,
                        tags=process_tags()), path
