"""Production meshes.

Defined as FUNCTIONS so importing this module never touches jax device
state; the dry-run sets ``xla_force_host_platform_device_count`` before
calling.  Axes:

  (data=16, model=16)            — one v5e pod slice, 256 chips
  (pod=2, data=16, model=16)     — two pods, 512 chips
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_mesh", "SINGLE_POD", "MULTI_POD"]

SINGLE_POD = (16, 16)
MULTI_POD = (2, 16, 16)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(data: int, model: int, pod: int = 1):
    """Arbitrary (pod ×) data × model mesh for tests/examples."""
    if pod > 1:
        return jax.make_mesh(
            (pod, data, model), ("pod", "data", "model"),
            axis_types=(jax.sharding.AxisType.Auto,) * 3)
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto,) * 2)
