"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this:
  1. builds the exact published config (``--arch``) and the assigned input
     shape (``--shape``) as ShapeDtypeStruct stand-ins,
  2. resolves sharding rules (TP/FSDP/ZeRO-1/SP) against the mesh,
  3. ``jax.jit(step).lower(...).compile()`` — a sharding mismatch, an
     unsupported collective, or a compile-time OOM is a bug in the system,
  4. captures the compiled command stream (repro.core) and derives the
     three-term roofline,
  5. prints ``memory_analysis()`` / ``cost_analysis()`` and appends a JSON
     record to the results file (resumable; reruns skip completed cells).

Usage:
  python -m repro.launch.dryrun --arch deepseek-7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all [--mesh both] [--out results/dryrun.jsonl]
"""
import os
# Must precede any jax import: jax locks the device count at first init.
# 512 placeholder host devices back the production meshes; nothing is ever
# allocated (ShapeDtypeStruct stand-ins only).
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs import ARCHS, SHAPES, resolve, skip_reason
from ..core import TraceSession, analyze, attribute, model_flops
from ..distributed.sharding import ShardingRules
from ..models import get_model
from ..runtime.steps import (init_all, make_decode_step, make_input_specs,
                             make_prefill_step, make_train_step)
from .mesh import make_production_mesh

RESULTS_DEFAULT = "results/dryrun.jsonl"


def _eval_shape_tree(fn, *args):
    return jax.eval_shape(fn, *args)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             keep_artifacts: bool = False,
             overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Lower+compile one cell; returns the JSON record."""
    import dataclasses as _dc

    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    cfg = resolve(ARCHS[arch], model_axis=mesh.shape["model"])
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    reason = skip_reason(cfg, shape)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single", "chips": n_chips,
    }
    if reason:
        rec.update({"status": "skip", "reason": reason})
        return rec

    model = get_model(cfg)
    rules = ShardingRules(mesh, cfg)
    from ..distributed.context import set_mesh
    from ..distributed.sharding import dp_axes as _dpa
    set_mesh(mesh, _dpa(mesh))
    if cfg.seq_shard and shape.kind in ("train", "prefill"):
        from jax.sharding import NamedSharding, PartitionSpec as P
        from ..distributed.sharding import dp_axes
        dp = dp_axes(mesh)
        sp = NamedSharding(mesh, P(dp if len(dp) > 1 else dp[0], "model", None))
        model.constraint = lambda x: jax.lax.with_sharding_constraint(x, sp)
    sess = TraceSession(name=f"{arch}:{shape_name}")
    cap = sess.capture
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            batch = make_input_specs(cfg, shape)
            params_s, opt_s = _eval_shape_tree(
                lambda: init_all(model, cfg, jax.random.PRNGKey(0)))
            p_specs = rules.param_specs(params_s)
            o_specs = jax.tree_util.tree_map_with_path(
                lambda path, leaf: rules.opt_spec(
                    "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                             for k in path), leaf.shape),
                opt_s)
            b_specs = rules.data_specs(batch)
            step = make_train_step(model, cfg)
            cs = cap.lower_and_compile(
                f"{arch}:{shape_name}", step,
                args=(params_s, opt_s, batch),
                in_shardings=(rules.to_shardings(p_specs),
                              rules.to_shardings(o_specs),
                              rules.to_shardings(b_specs)),
                donate_argnums=(0, 1))
            n_total, n_active = cfg.param_counts()
            tokens = shape.global_batch * shape.seq_len
            if cfg.family == "audio":
                tokens = shape.global_batch * (
                    shape.seq_len + shape.seq_len // cfg.enc_seq_ratio)
            mf = model_flops(n_active, tokens, "train")
        elif shape.kind == "prefill":
            batch = make_input_specs(cfg, shape)
            params_s = _eval_shape_tree(
                lambda: model.init_params(jax.random.PRNGKey(0)))
            p_specs = rules.param_specs(params_s)
            b_specs = rules.data_specs(batch)
            step = make_prefill_step(model, cfg, max_seq=shape.seq_len)
            cs = cap.lower_and_compile(
                f"{arch}:{shape_name}", step, args=(params_s, batch),
                in_shardings=(rules.to_shardings(p_specs),
                              rules.to_shardings(b_specs)))
            n_total, n_active = cfg.param_counts()
            tokens = shape.global_batch * shape.seq_len
            mf = model_flops(n_active, tokens, "inference")
        else:  # decode
            batch = make_input_specs(cfg, shape)
            params_s = _eval_shape_tree(
                lambda: model.init_params(jax.random.PRNGKey(0)))
            state_s = _eval_shape_tree(
                lambda: model.init_decode_state(shape.global_batch,
                                                shape.seq_len))
            p_specs = rules.param_specs(params_s)
            s_specs = rules.state_specs(state_s)
            b_specs = rules.data_specs(batch)
            step = make_decode_step(model, cfg)
            cs = cap.lower_and_compile(
                f"{arch}:{shape_name}", step,
                args=(params_s, state_s, batch["tokens"]),
                in_shardings=(rules.to_shardings(p_specs),
                              rules.to_shardings(s_specs),
                              rules.to_shardings(b_specs)["tokens"]),
                donate_argnums=(1,))
            n_total, n_active = cfg.param_counts()
            tokens = shape.global_batch  # one token per sequence
            mf = model_flops(n_active, tokens, "inference")

    wall = time.time() - t0
    try:  # raw compiler analyses (the summary below derives from these)
        print("memory_analysis:", cs.compiled.memory_analysis())
        print("cost_analysis:", {
            k: v for k, v in (cs.cost or {}).items()
            if k in ("flops", "bytes accessed", "optimal_seconds")})
    except Exception:
        pass
    rep = analyze(cs, chips=n_chips, model_flops_total=mf)
    # jax op_name metadata carries einsum specs / primitive paths, not python
    # function names — tag by the signatures each component uniquely emits.
    tags = {"attention_interior": (
                "bqhd,bkhd->bqhk", "bqhk,bkhd->bqhd",      # chunked/dense qk,pv
                "bhqk", "bgrd,bsgd->bgrs", "bgrs,bsgd->bgrd",  # dense + decode
                "while/body/closed_call/while/body"),      # chunk-loop softmax
            "ssd_interior": ("bqn,bkn->bqk", "bqkh,bkh,bkhp->bqhp",
                             "bqn,bhpn,bqh->bqhp", "bqhn,bqhp->bhpn",
                             "bqh,bqn->bqhn"),
            "moe": ("becd,edf->becf", "becf,efd->becd", "bsd,edf->ebsf",
                    "ebsf,efd->", "argsort", "bincount", "cumsum"),
            "loss": ("log_softmax", "logsumexp", "take_along_axis")}
    attr = {k: attribute(cs, *v) for k, v in tags.items()}
    rec.update({
        "status": "ok",
        "wall_s": round(wall, 2),
        "roofline": rep.to_dict(),
        "stream": cs.stream.summary(),
        "memory": cs.memory,
        "cost_flops": cs.xla_flops,
        "cost_bytes": cs.xla_bytes,
        "dropped_shardings": rules.dropped[:20],
        "attribution": attr,
        "model_params_total": n_total,
        "model_params_active": n_active,
        "trace": sess.summary(),
    })
    if keep_artifacts:
        rec["_captured"] = cs
    return rec


def run_pp_cell(arch: str, shape_name: str, multi_pod: bool,
                overrides: Optional[Dict[str, Any]] = None,
                keep_artifacts: bool = False,
                tokens_per_launch: int = 1) -> Dict[str, Any]:
    """Lower+compile the shard_map pipeline-parallel decode step."""
    import dataclasses as _dc
    from jax.sharding import NamedSharding
    from ..distributed.pp_decode import PPDecoder

    shape = SHAPES[shape_name]
    assert shape.kind == "decode", "PP path is a decode-serving feature"
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size
    cfg = resolve(ARCHS[arch], model_axis=mesh.shape["model"])
    if overrides:
        cfg = _dc.replace(cfg, **overrides)
    pp = PPDecoder(cfg, mesh, tokens_per_launch=tokens_per_launch)
    sess = TraceSession(name=f"{arch}:{shape_name}:pp")
    cap = sess.capture
    t0 = time.time()
    with mesh:
        params_s = jax.eval_shape(
            lambda: pp.init_params(jax.random.PRNGKey(0)))
        state_s = jax.eval_shape(
            lambda: pp.init_state(shape.global_batch, shape.seq_len))
        step = pp.make_step(shape.global_batch, shape.seq_len)
        to_sh = lambda specs: jax.tree_util.tree_map(
            lambda s: NamedSharding(mesh, s), specs,
            is_leaf=lambda x: isinstance(
                x, jax.sharding.PartitionSpec))
        tok_spec = jax.ShapeDtypeStruct(
            (shape.global_batch, tokens_per_launch), jnp.int32)
        cs = cap.lower_and_compile(
            f"{arch}:{shape_name}:pp", step,
            args=(params_s, state_s, tok_spec),
            in_shardings=(to_sh(pp.param_specs()),
                          to_sh(pp.state_specs()), None),
            donate_argnums=(1,))
    wall = time.time() - t0
    n_total, n_active = cfg.param_counts()
    mf = model_flops(n_active, shape.global_batch * tokens_per_launch,
                     "inference")
    rep = analyze(cs, chips=n_chips, model_flops_total=mf)
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "multi" if multi_pod else "single", "chips": n_chips,
           "status": "ok", "wall_s": round(wall, 2),
           "roofline": rep.to_dict(), "stream": cs.stream.summary(),
           "memory": cs.memory, "cost_flops": cs.xla_flops,
           "cost_bytes": cs.xla_bytes, "dropped_shardings": [],
           "attribution": {}, "model_params_total": n_total,
           "model_params_active": n_active, "pp": True,
           "tokens_per_launch": tokens_per_launch,
           "trace": sess.summary()}
    if keep_artifacts:
        rec["_captured"] = cs
    return rec


def _print_summary(rec: Dict[str, Any]) -> None:
    tag = f"{rec['arch']} × {rec['shape']} × {rec['mesh']}({rec['chips']})"
    if rec["status"] == "skip":
        print(f"SKIP {tag}: {rec['reason']}")
        return
    if rec["status"] == "error":
        print(f"FAIL {tag}: {rec['error'][:500]}")
        return
    r = rec["roofline"]
    m = rec["memory"]
    per_dev = (m.get("argument_size_in_bytes", 0)
               + m.get("temp_size_in_bytes", 0)) / 2**30
    print(f"OK   {tag}  wall={rec['wall_s']}s")
    print(f"     memory/device: args+temp={per_dev:.2f} GiB "
          f"(args={m.get('argument_size_in_bytes', 0)/2**30:.2f}, "
          f"out={m.get('output_size_in_bytes', 0)/2**30:.2f}, "
          f"temp={m.get('temp_size_in_bytes', 0)/2**30:.2f})")
    print(f"     roofline: compute={r['compute_s']*1e3:.3f}ms "
          f"memory={r['memory_s']*1e3:.3f}ms "
          f"collective={r['collective_s']*1e3:.3f}ms "
          f"-> {r['bottleneck']}-bound  "
          f"MF-ratio={r['model_flops_ratio']:.3f} "
          f"roofline-frac={r['roofline_fraction']:.3f}")
    cols = rec["stream"].get("collectives", {})
    if cols:
        tops = sorted(cols.items(), key=lambda kv: -kv[1])[:4]
        print("     collectives: " + ", ".join(
            f"{k}={v/2**20:.1f}MiB" for k, v in tops))


def _load_done(path: str) -> set:
    done = set()
    try:
        with open(path) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") in ("ok", "skip"):
                        done.add((r["arch"], r["shape"], r["mesh"]))
                except Exception:
                    pass
    except FileNotFoundError:
        pass
    return done


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=RESULTS_DEFAULT)
    ap.add_argument("--force", action="store_true", help="rerun completed cells")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    archs = list(ARCHS) if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    done = set() if args.force else _load_done(args.out)

    n_fail = 0
    for multi in meshes:
        mesh_name = "multi" if multi else "single"
        for arch in archs:
            for shape in shapes:
                if (arch, shape, mesh_name) in done:
                    print(f"SKIP (done) {arch} × {shape} × {mesh_name}")
                    continue
                try:
                    rec = run_cell(arch, shape, multi)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "chips": 512 if multi else 256,
                           "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    n_fail += 1
                _print_summary(rec)
                with open(args.out, "a") as f:
                    f.write(json.dumps(
                        {k: v for k, v in rec.items()
                         if not k.startswith("_")}) + "\n")
    if n_fail:
        raise SystemExit(f"{n_fail} cell(s) failed")


if __name__ == "__main__":
    main()
