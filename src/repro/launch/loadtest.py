"""Traffic-replay load harness for the continuous-batching server.

    PYTHONPATH=src python -m repro.launch.loadtest --arch gemma-2b --quick

Generates seeded Poisson traffic (mixed prompt/output lengths), replays it
against a :class:`~repro.runtime.server.ContinuousBatchingServer` — by
default in real time, with a producer thread submitting into the running
decode loop — and reports p50/p99 per-request latency, tokens/sec, and
tokens-per-doorbell, all sourced from one ``TraceSession`` timeline.

``--verify N`` (on by default under ``--quick``) re-decodes N of the
replayed requests through one-shot ``Server.serve()`` and checks the token
streams are identical — the continuous-batching correctness invariant.
``--json PATH`` writes the machine-readable run record, including the final
session ``summary()`` and per-sink drop/sample accounting.

Observability options (``repro.obs``): ``--live [PORT]`` serves the
engine's live summary over HTTP while the replay runs (``GET /summary``,
``GET /stream``); ``--trace PATH`` streams the full event timeline to a
JSONL shard through a non-blocking :class:`~repro.obs.AsyncSink` (tagged
with host/process ids, ready for ``python -m repro.obs.aggregate``);
``--sample KIND=N`` decimates high-rate kinds on that shard with exact
sampled-away counts.

Every run also carries a :class:`~repro.obs.profile.SpanProfile` sink, so
the report — and the ``--json`` record, under ``"span_profile"`` — includes
per-request causal attribution: doorbells, payload bytes, and graph
launches per ``serve.request`` span, with wall-time p50/p90/p99 from
streaming histograms.  ``--store [ROOT]`` appends the run's metrics and
span attribution to the persistent store (:mod:`repro.obs.store`;
``results/metrics/`` by default) for cross-run trend queries.
"""
from __future__ import annotations

import argparse
import json
from typing import List

from ..configs import ARCHS, SMOKE_ARCHS


def _csv_ints(s: str) -> tuple:
    return tuple(int(x) for x in s.split(",") if x)


def _sample_spec(pairs) -> dict:
    out = {}
    for p in pairs or ():
        kind, _, n = p.partition("=")
        if not n:
            raise argparse.ArgumentTypeError(
                f"--sample expects KIND=N, got {p!r}")
        out[kind] = int(n)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.launch.loadtest")
    ap.add_argument("--arch", default="gemma-2b", choices=list(ARCHS))
    ap.add_argument("--full", action="store_true",
                    help="published config (default: smoke variant)")
    ap.add_argument("--quick", action="store_true",
                    help="CI-scale run: fewer requests, verification on")
    ap.add_argument("--batch", type=int, default=4, help="KV slots")
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--tokens-per-launch", type=int, default=None,
                    help="unset -> tuned policy (python -m repro.tune)")
    ap.add_argument("--max-pending", type=int, default=256)
    ap.add_argument("--admission", default="reject",
                    choices=("reject", "drop_oldest"))
    ap.add_argument("--kv", default="dense", choices=("dense", "paged"),
                    help="KV-cache backend (paged adds block tables + "
                         "shared-prefix page reuse)")
    ap.add_argument("--kv-page-tokens", type=int, default=None,
                    help="paged backend page size in tokens "
                         "(unset -> tuned policy, fallback 16)")
    ap.add_argument("--kv-pages", type=int, default=None,
                    help="paged pool size in pages (unset -> every slot "
                         "fully grown: exhaustion impossible)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="max tokens per prefill launch; longer prompts "
                         "are chunked and interleaved with decode iters "
                         "(unset -> tuned policy, fallback 0 = off)")
    ap.add_argument("--sched", default="fifo",
                    choices=("fifo", "priority", "fair"),
                    help="admission scheduling policy")
    ap.add_argument("--prefix-len", type=int, default=0,
                    help="shared seeded prefix tokens on every prompt "
                         "(system-prompt traffic; exercises paged "
                         "prefix reuse)")
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--rate", type=float, default=100.0,
                    help="mean Poisson arrival rate, requests/s")
    ap.add_argument("--prompt-lens", type=_csv_ints, default=(4, 8, 16))
    ap.add_argument("--new-tokens", type=_csv_ints, default=(4, 8, 16))
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-realtime", dest="realtime", action="store_false",
                    help="submit everything up front, then drain")
    ap.add_argument("--speed", type=float, default=1.0,
                    help="replay speed-up for the arrival clock")
    ap.add_argument("--verify", type=int, default=None, metavar="N",
                    help="check N requests against one-shot serve() "
                         "(default: 4 under --quick, else 0)")
    ap.add_argument("--json", default="", help="write run record here")
    ap.add_argument("--live", type=int, default=None, nargs="?", const=0,
                    metavar="PORT",
                    help="serve the live summary over HTTP during the run "
                         "(PORT omitted or 0 -> ephemeral)")
    ap.add_argument("--trace", default="", metavar="PATH",
                    help="stream the event timeline to a JSONL shard "
                         "through a non-blocking AsyncSink")
    ap.add_argument("--sample", action="append", metavar="KIND=N",
                    help="keep 1-in-N events of KIND on the --trace shard "
                         "(repeatable; barriers always kept)")
    ap.add_argument("--store", default=None, nargs="?", const="",
                    metavar="ROOT",
                    help="append run metrics + span attribution to the "
                         "persistent metrics store (default root: "
                         "results/metrics, or REPRO_METRICS_DIR)")
    args = ap.parse_args(argv)

    if args.quick:
        args.requests = min(args.requests, 16)
        args.rate = max(args.rate, 100.0)
        args.max_seq = min(args.max_seq, 64)
        args.prompt_lens = (4, 8)
        args.new_tokens = (5, 9)
    verify_n = args.verify if args.verify is not None else (
        4 if args.quick else 0)

    from ..core.session import JsonlSink, TraceSession
    from ..distributed.context import process_tags, shard_path
    from ..obs.profile import SpanProfile
    from ..runtime.server import ContinuousBatchingServer, Request, Server
    from ..runtime.traffic import TrafficSpec, generate, replay

    cfg = (ARCHS if args.full else SMOKE_ARCHS)[args.arch]
    spec = TrafficSpec(n_requests=args.requests, rate=args.rate,
                       prompt_lens=args.prompt_lens,
                       new_tokens=args.new_tokens, seed=args.seed,
                       prefix_len=args.prefix_len)
    arrivals = generate(spec, vocab_size=cfg.vocab_size)

    # per-span causal attribution rides every run: feeds the report, the
    # --json record, and (with --store) the persistent metrics store
    prof = SpanProfile(name="loadtest")
    extra_sinks: List = [prof]
    if args.trace:
        from ..obs import AsyncSink, SamplingSink
        shard = shard_path(args.trace)
        inner = JsonlSink(shard)
        sample = _sample_spec(args.sample)
        if sample:
            inner = SamplingSink(inner, every=sample)
        extra_sinks.append(AsyncSink(inner))
        print(f"tracing -> {shard} (async"
              + (f", sampling {sample}" if sample else "") + ")")

    with TraceSession(name="loadtest", sinks=extra_sinks,
                      tags=process_tags()) as sess:
        eng = ContinuousBatchingServer(
            cfg, batch_size=args.batch, max_seq=args.max_seq,
            tokens_per_launch=args.tokens_per_launch, seed=args.seed,
            session=sess, max_pending=args.max_pending,
            admission=args.admission, kv=args.kv,
            kv_page_tokens=args.kv_page_tokens, kv_pages=args.kv_pages,
            prefill_chunk=args.prefill_chunk, sched=args.sched)
        live_srv = None
        if args.live is not None:
            live_srv = eng.start_live_endpoint(port=args.live)
            print(f"live summary endpoint: {live_srv.url}/summary "
                  f"(stream: {live_srv.url}/stream)")
        sess.barrier("loadtest.start")
        print(f"loadtest: arch={cfg.name} slots={args.batch} T={eng.T} "
              f"requests={spec.n_requests} rate={spec.rate}/s "
              f"realtime={args.realtime} admission={args.admission} "
              f"kv={eng.kv.name} chunk={eng.kv.chunk} sched={args.sched}")
        try:
            tickets, metrics = replay(eng, arrivals, realtime=args.realtime,
                                      speed=args.speed)
        finally:
            if live_srv is not None:
                eng.stop_live_endpoint()
        sess.flush()                    # drain async sinks before reading
        summary = sess.summary()
        sink_stats = sess.sink_stats()

    print(f"requests={metrics['requests']} completed={metrics['completed']} "
          f"evicted={metrics['evicted']} rejected={metrics['rejected']}")
    print(f"latency  p50={metrics['latency_p50_s']*1e3:.1f}ms "
          f"p99={metrics['latency_p99_s']*1e3:.1f}ms   "
          f"ttft p50={metrics['ttft_p50_s']*1e3:.1f}ms "
          f"p99={metrics['ttft_p99_s']*1e3:.1f}ms")
    print(f"throughput {metrics['tokens_per_s']:.1f} tokens/s   "
          f"tokens/doorbell={metrics['tokens_per_doorbell']:.2f} "
          f"({metrics['new_tokens']} tokens / {metrics['doorbells']} "
          f"doorbells)")
    kv = metrics["kv"]
    print(f"kv[{kv['backend']}] prefill launches={kv['prefill_launches']} "
          f"payload={kv['prefill_payload_bytes']}B "
          f"chunked={kv['chunked_prompts']}"
          + (f"  pages peak={kv['pages_peak']}/{kv['pages_total']} "
             f"reused={kv['pages_reused']} "
             f"prefix_hits={kv['prefix_hits']}"
             if kv["backend"] == "paged" else ""))
    req_attr = prof.path("serve.request")
    if req_attr:
        db, wall = req_attr["doorbells_per_span"], req_attr["wall_s"]
        print(f"per-request attribution: doorbells p50={db['p50']:.1f} "
              f"p99={db['p99']:.1f}  wall p50={wall['p50']*1e3:.1f}ms "
              f"p99={wall['p99']*1e3:.1f}ms  "
              f"payload={req_attr['payload_bytes']}B over "
              f"{req_attr['spans']} requests")

    ok = True
    if verify_n:
        served = [t for t in tickets if t.status in ("done", "evicted")]
        sample = served[:verify_n]
        solo = Server(cfg, batch_size=1, max_seq=args.max_seq,
                      tokens_per_launch=1, seed=args.seed)
        n_match = 0
        for t in sample:
            # evicted requests were KV-truncated: compare the served prefix
            r = Request(t.uid, t.request.prompt,
                        max_new_tokens=len(t.tokens))
            solo.serve([r])
            if r.tokens == t.tokens:
                n_match += 1
            else:
                ok = False
                print(f"equivalence MISMATCH uid={t.uid}: "
                      f"continuous={t.tokens} oneshot={r.tokens}")
        print(f"equivalence: {'OK' if ok else 'FAILED'} "
              f"({n_match}/{len(sample)} requests match one-shot serve)")

    if args.json:
        record = {
            "arch": cfg.name,
            "engine": {"batch": args.batch, "tokens_per_launch": eng.T,
                       "max_seq": args.max_seq,
                       "max_pending": args.max_pending,
                       "admission": args.admission,
                       "realtime": args.realtime,
                       "sched": args.sched},
            # KV backend footprint: prefill launches/payload, page pool
            # occupancy, prefix-hit reuse — the dense-vs-paged comparison
            # the README table and BENCH kv section are built from
            "kv": metrics["kv"],
            "traffic": spec.to_dict(),
            "metrics": metrics,
            "session_summary": summary,
            # per-sink loss accounting: how much observability this run
            # traded away (async drops, sampled-away events) — BENCH
            # artifacts carry it so the loss itself is tracked over PRs
            "sink_stats": sink_stats,
            # causal attribution: per-span-path doorbell/payload/launch
            # totals plus wall/doorbell/payload percentile summaries from
            # the streaming histograms (serve.request = one span/request)
            "span_profile": prof.snapshot(),
            "tickets": [t.to_dict() for t in tickets],
            "verified": {"n": verify_n, "ok": ok} if verify_n else None,
        }
        with open(args.json, "w") as f:
            json.dump(record, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}")

    if args.store is not None:
        from ..obs.store import MetricsStore, new_run_id
        store = MetricsStore(root=args.store or None)
        run_id = new_run_id()
        numeric = {k: float(v) for k, v in metrics.items()
                   if isinstance(v, (int, float))}
        store.append("loadtest", numeric, run_id=run_id,
                     meta={"arch": cfg.name, "slots": args.batch,
                           "tokens_per_launch": eng.T})
        store.append("span_profile", prof.store_metrics(), run_id=run_id,
                     meta={"arch": cfg.name})
        print(f"stored run {run_id} -> {store.root}")

    print(prof.report())
    print(eng.session.report(max_events=20, kinds=("progress",)))
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
