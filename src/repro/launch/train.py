"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --steps 50 \
        [--smoke] [--steps-per-launch 4] [--ckpt-dir /tmp/ckpt] \
        [--grad-compression int8] [--seq 256 --batch 8] \
        [--trace trace.jsonl] [--profile]

On this CPU container use ``--smoke`` (reduced config); on a real slice the
full config + production mesh apply (see launch/dryrun.py for the sharding).

``--trace PATH`` writes this process's fleet-identified JSONL shard (tagged
``host``/``process``, per-process filename) for ``repro.obs.aggregate`` /
``repro.obs.export``; ``--profile`` prints per-``train.step`` span
attribution (doorbells, payload, wall p50/p90/p99).
"""
from __future__ import annotations

import argparse

from ..configs import ARCHS, SMOKE_ARCHS
from ..configs.shapes import ShapeConfig
from ..runtime.trainer import Trainer
from ..tune.policy import load_policy_for
from .mesh import fleet_session


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=list(ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps-per-launch", type=int, default=None,
                    help="unset -> auto-apply the tuned policy "
                         "(python -m repro.tune), else 4")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--grad-compression", default=None,
                    choices=[None, "int8"])
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write this process's JSONL trace shard "
                         "(fleet-tagged, per-process filename)")
    ap.add_argument("--profile", action="store_true",
                    help="print per-span command attribution after the run")
    args = ap.parse_args()

    cfg = (SMOKE_ARCHS if args.smoke else ARCHS)[args.arch]
    shape = ShapeConfig("train", args.seq, args.batch, "train")
    spl = args.steps_per_launch
    if spl is None and load_policy_for(cfg, activate=False) is None:
        spl = 4                      # legacy CLI default when untuned
    session, shard = fleet_session("train", trace_path=args.trace)
    prof = None
    if args.profile:
        from ..obs.profile import SpanProfile
        prof = SpanProfile(name="train")
        session.add_sink(prof)
    tr = Trainer(cfg, shape, steps_per_launch=spl,
                 ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                 grad_compression=args.grad_compression,
                 peak_lr=args.lr, seed=args.seed, session=session)
    if tr.policy is not None:
        print(f"policy: {tr.policy.arch} knobs={tr.policy.knobs} "
              f"objective={tr.policy.objective.get('after')}")
    if args.ckpt_dir and tr.maybe_restore():
        print(f"restored at step {tr.step}")
    out = tr.train(args.steps)
    print(out)
    print(tr.submission_report())
    print(tr.trace_report(max_events=30))
    if prof is not None:
        print(prof.report())
    session.close()
    if shard:
        print(f"trace shard: {shard}")


if __name__ == "__main__":
    main()
