"""Listing-1-style decoded submission reports.

The paper's Listing 1 shows a captured doorbell interception: the GPFIFO
summary (GET/PUT indices, base, new entry) followed by decoded pushbuffer
entries.  This module renders the equivalent for a captured JAX submission
unit: the submission summary (executable fingerprint, footprint, dispatch
stats) followed by decoded command-stream entries with engine attribution.
"""
from __future__ import annotations

from typing import Any, Optional

from .capture import CapturedStream
from .doorbell import DoorbellTracker

__all__ = ["render_submission", "render_roofline_row"]


def _fmt_bytes(n: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(n) < 1024 or unit == "TiB":
            return f"{n:.1f}{unit}" if unit != "B" else f"{int(n)}B"
        n /= 1024
    return f"{n:.1f}TiB"


def render_submission(cs: CapturedStream,
                      tracker: Optional[DoorbellTracker] = None,
                      max_entries: int = 40) -> str:
    """Render a captured stream like the paper's Listing 1."""
    lines = []
    lines.append(f"Submission captured: {cs.name}")
    lines.append("==== SUBMISSION SUMMARY ====")
    fp = getattr(cs.compiled, "runtime_executable", None)
    lines.append(f"executable        {type(cs.compiled).__name__}"
                 f"@{hex(id(cs.compiled))}")
    del fp
    lines.append(f"command footprint {_fmt_bytes(cs.command_bytes)} "
                 f"({cs.n_ops} decoded entries)")
    lines.append(f"lower/compile     {cs.lower_time_s*1e3:.1f} ms / "
                 f"{cs.compile_time_s*1e3:.1f} ms")
    if cs.memory:
        arg = cs.memory.get("argument_size_in_bytes", 0)
        out = cs.memory.get("output_size_in_bytes", 0)
        tmp = cs.memory.get("temp_size_in_bytes", 0)
        code = cs.memory.get("generated_code_size_in_bytes", 0)
        lines.append(f"memory            args={_fmt_bytes(arg)} "
                     f"out={_fmt_bytes(out)} temp={_fmt_bytes(tmp)} "
                     f"code={_fmt_bytes(code)}")
    lines.append(f"flops/device      {cs.flops:.3e} "
                 f"(xla cost_analysis: {cs.xla_flops:.3e})")
    lines.append(f"hbm bytes/device  {_fmt_bytes(cs.memory_bytes)}")
    lines.append(f"ici bytes/device  {_fmt_bytes(cs.collective_link_bytes)}")
    colls = cs.stream.collective_bytes_by_op()
    if colls:
        lines.append("collective breakdown:")
        for op, b in sorted(colls.items(), key=lambda kv: -kv[1]):
            n = cs.stream.collective_counts().get(op, 0)
            lines.append(f"  {op:<22s} x{n:<6d} {_fmt_bytes(b)}")
    if tracker is not None:
        lines.append(f"doorbell writes   {tracker.count}")
    lines.append("==== END SUBMISSION SUMMARY ====")
    lines.append(f"Command-stream entries: {cs.n_ops}"
                 + (f" (showing first {max_entries})"
                    if cs.n_ops > max_entries else ""))
    for e in cs.stream.entries[:max_entries]:
        lines.append("  " + e.describe())
    if cs.n_ops > max_entries:
        lines.append(f"  ... {cs.n_ops - max_entries} more")
    return "\n".join(lines)


def render_roofline_row(rep: Any) -> str:
    """One fixed-width roofline table row."""
    return (f"{rep.name:<44s} {rep.chips:>5d} "
            f"{rep.compute_s*1e3:>10.3f} {rep.memory_s*1e3:>10.3f} "
            f"{rep.collective_s*1e3:>10.3f} {rep.bottleneck:<10s} "
            f"{rep.model_flops_ratio:>6.3f} {rep.roofline_fraction:>6.3f}")
