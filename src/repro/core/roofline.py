"""Three-term roofline from captured command streams.

The paper's goal is performance *attribution*: split an observed duration
into the stage that actually produced it (engine execution vs submission path
vs software overhead).  At pod scale the same question is which hardware
term bounds a step: MXU compute, HBM traffic, or ICI collective traffic.

All terms are derived from the *captured command stream* of the compiled
executable (per-device, post-SPMD), never measured on this CPU container:

    compute_s    = FLOPs_per_device    / PEAK_FLOPS
    memory_s     = HBM_bytes_per_device/ HBM_BW
    collective_s = ICI_bytes_per_device/ ICI_BW

Hardware model (TPU v5e, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

__all__ = ["HW", "TPU_V5E", "RooflineReport", "analyze", "model_flops"]


@dataclasses.dataclass(frozen=True)
class HW:
    name: str
    peak_flops: float          # per chip, bf16
    hbm_bw: float              # bytes/s per chip
    ici_bw: float              # bytes/s per link
    hbm_bytes: float           # capacity per chip


TPU_V5E = HW(name="tpu_v5e", peak_flops=197e12, hbm_bw=819e9,
             ici_bw=50e9, hbm_bytes=16 * 2**30)


def model_flops(n_params_active: float, tokens: float,
                mode: str = "train") -> float:
    """Useful model FLOPs: 6·N·D for training, 2·N·D for inference."""
    k = 6.0 if mode == "train" else 2.0
    return k * n_params_active * tokens


@dataclasses.dataclass
class RooflineReport:
    name: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    flops_per_device: float
    hbm_bytes_per_device: float
    ici_bytes_per_device: float
    model_flops_total: float = 0.0
    xla_flops_per_device: float = 0.0
    hw: HW = TPU_V5E

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Lower-bound step time: max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def model_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS — how much compiled compute is useful."""
        total_hlo = self.flops_per_device * self.chips
        return self.model_flops_total / total_hlo if total_hlo else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / bounded step time.

        1.0 means the step runs at the compute roofline with zero redundant
        FLOPs; lower values quantify headroom in the dominant term.
        """
        if self.step_time_s <= 0:
            return 0.0
        useful_s = (self.model_flops_total / self.chips) / self.hw.peak_flops
        return useful_s / self.step_time_s

    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time_s,
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "ici_bytes_per_device": self.ici_bytes_per_device,
            "model_flops_total": self.model_flops_total,
            "model_flops_ratio": self.model_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "xla_flops_per_device": self.xla_flops_per_device,
            "hw": self.hw.name,
        }


def attribute(captured: Any, *tags: str) -> Dict[str, float]:
    """Totals for command-stream entries whose jax-level op_path matches any
    tag (e.g. 'chunked_causal_attention', 'ssd_chunked') — used to credit
    Pallas kernels: on TPU the kernel keeps its tiles in VMEM, so the tagged
    interior's HBM traffic collapses to its I/O working set."""
    flops = mem = ici = 0.0
    for e in captured.stream.entries:
        if any(t in e.op_path for t in tags):
            flops += e.flops * e.multiplier
            mem += (e.result_bytes + e.operand_bytes) * e.multiplier
            ici += e.link_bytes * e.multiplier
    return {"flops": flops, "memory_bytes": mem, "ici_bytes": ici}


def adjusted(report: RooflineReport, d_flops: float = 0.0,
             d_mem: float = 0.0, d_ici: float = 0.0,
             name: Optional[str] = None) -> RooflineReport:
    """New report with per-device deltas applied (kernel credit, modeled
    optimization).  Deltas are per-device bytes/FLOPs, may be negative."""
    import dataclasses as _dc
    flops = max(0.0, report.flops_per_device + d_flops)
    mem = max(0.0, report.hbm_bytes_per_device + d_mem)
    ici = max(0.0, report.ici_bytes_per_device + d_ici)
    return _dc.replace(
        report,
        name=name or report.name,
        flops_per_device=flops,
        hbm_bytes_per_device=mem,
        ici_bytes_per_device=ici,
        compute_s=flops / report.hw.peak_flops,
        memory_s=mem / report.hw.hbm_bw,
        collective_s=ici / report.hw.ici_bw)


def analyze(captured: Any, chips: int, model_flops_total: float = 0.0,
            hw: HW = TPU_V5E, name: Optional[str] = None) -> RooflineReport:
    """Roofline terms for one captured stream (see ``core.capture``)."""
    flops = float(captured.flops)
    mem_b = float(captured.memory_bytes)
    ici_b = float(captured.collective_link_bytes)
    return RooflineReport(
        name=name or captured.name, chips=chips,
        compute_s=flops / hw.peak_flops,
        memory_s=mem_b / hw.hbm_bw,
        collective_s=ici_b / hw.ici_bw,
        flops_per_device=flops,
        hbm_bytes_per_device=mem_b,
        ici_bytes_per_device=ici_b,
        model_flops_total=model_flops_total,
        xla_flops_per_device=float(captured.xla_flops),
        hw=hw)
