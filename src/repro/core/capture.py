"""Command-stream capture at the submission boundary.

The paper's watchpoint traps the userspace driver at the exact moment a
submission is committed (the doorbell write), guaranteeing a complete and
consistent view of the command stream.  In JAX the submission unit is a
compiled executable; the commit boundary is ``.lower()``/``.compile()`` and
each subsequent dispatch.  :class:`CommandStreamCapture` owns that boundary:
everything that is lowered/compiled through it is recorded — never sampled,
never partial — together with the compiler's own cost/memory analyses and the
decoded :class:`~repro.core.hlo.CommandStream`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Sequence

import jax

from . import hlo
from .session import TraceSession, resolve_session

__all__ = ["CapturedStream", "CommandStreamCapture", "capture_fn"]


def _normalize_cost(cost: Any) -> Dict[str, float]:
    """jax returns either a dict or a 1-element list of dicts depending on
    version/backend."""
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


@dataclasses.dataclass
class CapturedStream:
    """One captured submission unit (≙ one GPFIFO entry + its pushbuffer)."""

    name: str
    lowered: Any
    compiled: Any
    stream: hlo.CommandStream           # decoded command stream
    cost: Dict[str, float]              # XLA cost_analysis (per-device)
    memory: Dict[str, int]              # XLA memory_analysis fields
    lower_time_s: float = 0.0
    compile_time_s: float = 0.0

    # -- convenience -------------------------------------------------------
    @property
    def xla_flops(self) -> float:
        return float(self.cost.get("flops", 0.0))

    @property
    def xla_bytes(self) -> float:
        return float(self.cost.get("bytes accessed", 0.0))

    @property
    def flops(self) -> int:
        """Trip-count-weighted FLOPs from the decoded stream (per device)."""
        return self.stream.total_flops

    @property
    def memory_bytes(self) -> int:
        return self.stream.memory_bytes

    @property
    def collective_link_bytes(self) -> int:
        return self.stream.collective_link_bytes

    @property
    def command_bytes(self) -> int:
        return self.stream.text_bytes

    @property
    def n_ops(self) -> int:
        return self.stream.n_ops

    def summary(self) -> Dict[str, Any]:
        out = dict(self.stream.summary())
        out.update({
            "name": self.name,
            "xla_flops": self.xla_flops,
            "xla_bytes_accessed": self.xla_bytes,
            "lower_time_s": round(self.lower_time_s, 4),
            "compile_time_s": round(self.compile_time_s, 4),
            **{f"mem_{k}": v for k, v in self.memory.items()},
        })
        return out


def _memory_analysis_dict(compiled: Any) -> Dict[str, int]:
    try:
        m = compiled.memory_analysis()
    except Exception:
        return {}
    if m is None:
        return {}
    out: Dict[str, int] = {}
    for k in ("generated_code_size_in_bytes", "argument_size_in_bytes",
              "output_size_in_bytes", "alias_size_in_bytes",
              "temp_size_in_bytes"):
        v = getattr(m, k, None)
        if v is not None:
            out[k] = int(v)
    return out


class CommandStreamCapture:
    """Owns the lower/compile boundary and records every submission unit.

    Usage::

        cap = CommandStreamCapture()
        cs = cap.lower_and_compile("train_step", step_fn, args=(specs,),
                                   in_shardings=..., out_shardings=...)
        cs.stream.collective_link_bytes   # decoded ICI traffic
    """

    def __init__(self, session: Optional[TraceSession] = None) -> None:
        self.captured: Dict[str, CapturedStream] = {}
        self._session = session

    def _emit(self, cs: CapturedStream, t: float) -> None:
        """Publish one ``compile`` event for a captured submission unit."""
        sess = resolve_session(self._session)
        if sess is not None:
            sess.emit("compile", cs.name,
                      dur_s=cs.lower_time_s + cs.compile_time_s, t=t,
                      command_bytes=cs.command_bytes, n_ops=cs.n_ops,
                      flops=cs.flops, memory_bytes=cs.memory_bytes)

    def lower_and_compile(
        self,
        name: str,
        fn: Callable,
        args: Sequence[Any] = (),
        kwargs: Optional[Dict[str, Any]] = None,
        in_shardings: Any = None,
        out_shardings: Any = None,
        donate_argnums: Sequence[int] = (),
        static_argnums: Sequence[int] = (),
        compiler_options: Optional[Dict[str, Any]] = None,
        keep_lowered_text: bool = False,
    ) -> CapturedStream:
        kwargs = kwargs or {}
        jit_kwargs: Dict[str, Any] = {}
        if in_shardings is not None:
            jit_kwargs["in_shardings"] = in_shardings
        if out_shardings is not None:
            jit_kwargs["out_shardings"] = out_shardings
        if donate_argnums:
            jit_kwargs["donate_argnums"] = tuple(donate_argnums)
        if static_argnums:
            jit_kwargs["static_argnums"] = tuple(static_argnums)
        jitted = jax.jit(fn, **jit_kwargs)

        t0 = time.perf_counter()
        lowered = jitted.lower(*args, **kwargs)
        t1 = time.perf_counter()
        compiled = (lowered.compile(compiler_options=compiler_options)
                    if compiler_options else lowered.compile())
        t2 = time.perf_counter()

        text = compiled.as_text()
        stream = hlo.parse_hlo(text)
        cost = _normalize_cost(getattr(compiled, "cost_analysis", lambda: {})())
        memory = _memory_analysis_dict(compiled)
        cs = CapturedStream(
            name=name, lowered=lowered if keep_lowered_text else None,
            compiled=compiled, stream=stream, cost=cost, memory=memory,
            lower_time_s=t1 - t0, compile_time_s=t2 - t1)
        self.captured[name] = cs
        self._emit(cs, t=t0)
        return cs

    def capture_compiled(self, name: str, compiled: Any) -> CapturedStream:
        """Capture an already-compiled executable (e.g. from elsewhere)."""
        text = compiled.as_text()
        cs = CapturedStream(
            name=name, lowered=None, compiled=compiled,
            stream=hlo.parse_hlo(text),
            cost=_normalize_cost(getattr(compiled, "cost_analysis", lambda: {})()),
            memory=_memory_analysis_dict(compiled))
        self.captured[name] = cs
        self._emit(cs, t=time.perf_counter())
        return cs


def capture_fn(fn: Callable, *args, name: str = "fn", **kw) -> CapturedStream:
    """One-shot convenience wrapper."""
    return CommandStreamCapture().lower_and_compile(name, fn, args=args, **kw)
