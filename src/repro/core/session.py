"""Unified trace session: one submission-event timeline for the whole stack.

The paper's methodological core is a *single, complete* observation point —
the doorbell watchpoint — through which every submission passes exactly once.
Our reproduction previously scattered that visibility across five disjoint
primitives (capture, doorbell, DMA, graph launch, progress) that consumers
wired by hand with no shared clock or event model.  :class:`TraceSession` is
the watchpoint analogue for the JAX stack: every instrumented code path —
compile, dispatch, transfer, graph launch, progress fence — reports into one
session, under one monotonic sequence number and one timestamp base, so the
merged timeline interleaves events in true submission order.

Activation follows the watchpoint model too: installing a session makes it
ambient.  ``with TraceSession(...) as sess:`` publishes the session through a
:mod:`contextvars` variable; any tracker, mover, launcher, or capture created
*without* an explicit session reports to the ambient one while the block is
active (and stays silent outside it — legacy standalone behaviour is
unchanged).  Explicit injection (``DoorbellTracker(session=sess)``) is still
supported and wins over the ambient session.

Events flow to pluggable sinks.  The sink protocol is deliberately small —
``emit(event)`` is required; ``flush()``, ``close()``, and ``stats()`` are
optional (see :class:`Sink`).  Two sinks are built in:

* :class:`RingBufferSink` — bounded in-memory ring (always installed; backs
  :meth:`TraceSession.timeline`);
* :class:`JsonlSink` — append-only JSONL file for offline analysis.

:mod:`repro.obs` layers production sinks on the same protocol
(:class:`~repro.obs.AsyncSink`, :class:`~repro.obs.SamplingSink`,
:class:`~repro.obs.LiveSummary`) plus fleet-wide shard aggregation; sessions
there are *tagged* (``tags={"host": ..., "process": ...}``) so every event's
``meta`` carries its origin and per-process JSONL shards can be merged into
one cross-host submission-ordered timeline.

Causal attribution rides on *spans*: ``with sess.span("request", uid=7):``
opens a nestable, contextvar-scoped span, and every event emitted under it
is stamped with the span's identity (``span_id``/``parent_span_id``/
``span_path``/``span_ids``) — each doorbell, transfer, and graph launch is
tied back to the API call that caused it.  Closing a span emits an
:data:`SPAN_EVENT` close event; :mod:`repro.obs.profile` turns those into
per-span command-attribution profiles and :mod:`repro.obs.export` renders
them as nested Perfetto duration events.

:meth:`TraceSession.report` renders the Listing-1-style interleaved timeline;
:meth:`TraceSession.summary` gives JSON-serializable per-kind accounting.
"""
from __future__ import annotations

import collections
import contextlib
import contextvars
import dataclasses
import json
import threading
import time
import warnings
from typing import (Any, Callable, Dict, IO, Iterable, Iterator, List,
                    Optional, Tuple)

__all__ = [
    "EVENT_KINDS",
    "BARRIER_EVENT",
    "SPAN_EVENT",
    "TraceEvent",
    "Sink",
    "SpanFrame",
    "SpanHandle",
    "RingBufferSink",
    "JsonlSink",
    "TraceSession",
    "current_session",
    "ambient_span",
]

#: The five submission-event kinds, mirroring the subsystems they unify:
#: ``compile`` (capture.py), ``dispatch`` (doorbell.py), ``transfer``
#: (dma.py), ``graph_launch`` (graphs.py), ``progress`` (semaphore.py).
EVENT_KINDS = ("compile", "dispatch", "transfer", "graph_launch", "progress")

#: Event name used by :meth:`TraceSession.barrier`.  Barrier events carry a
#: shared id plus a wall-clock reading in ``meta``; :mod:`repro.obs.aggregate`
#: uses them to align the per-process monotonic clocks of JSONL shards.
BARRIER_EVENT = "obs.barrier"

#: Event name emitted when a span closes (see :meth:`TraceSession.span`).
#: A span-close event records the span's start time (``t``), duration
#: (``dur_s``), identity (``span``/``span_id``/``parent_span_id``/
#: ``span_path``/``span_ids``) and any caller attributes — the causal unit
#: :mod:`repro.obs.profile` attributes command traffic to and
#: :mod:`repro.obs.export` renders as a Perfetto duration event.
SPAN_EVENT = "obs.span"


class Sink:
    """The sink protocol (documentation class — duck typing is enough).

    A sink must provide ``emit(event)``; it may provide ``flush()``,
    ``close()``, and ``stats()``.  ``emit`` is always called under the owning
    session's lock, but a sink shared across sessions (or wrapped in
    :class:`~repro.obs.AsyncSink`'s writer thread) must synchronize its own
    mutable state.  ``stats()`` returns a JSON-serializable dict and should
    include a ``"sink"`` key naming the sink type plus whatever loss
    accounting the sink keeps (``dropped``, ``sampled_away``, ...) — this is
    how observability *loss* stays observable.
    """

    def emit(self, event: "TraceEvent") -> None:  # pragma: no cover
        raise NotImplementedError

    def flush(self) -> None:
        pass

    def close(self) -> None:
        pass

    def stats(self) -> Dict[str, Any]:
        return {"sink": type(self).__name__}


@dataclasses.dataclass(frozen=True)
class TraceEvent:
    """One submission event on the unified timeline.

    ``seq`` is unique and monotonic *across all kinds* within a session —
    the analogue of observing every doorbell write from one watchpoint.
    ``t`` is seconds since the session's timestamp base (``perf_counter``
    at session construction), so events from different subsystems are
    directly comparable.
    """

    seq: int
    kind: str                   # one of EVENT_KINDS
    name: str                   # subsystem-chosen label (e.g. "train_step")
    t: float                    # seconds since session t0
    dur_s: float = 0.0          # host time to submit/enqueue
    complete_s: float = 0.0     # host time to completion (0 if not fenced)
    payload_bytes: int = 0      # bytes riding this submission
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "seq": self.seq, "kind": self.kind, "name": self.name,
            "t": self.t, "dur_s": self.dur_s, "complete_s": self.complete_s,
            "payload_bytes": self.payload_bytes, "meta": self.meta,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "TraceEvent":
        return cls(seq=int(d["seq"]), kind=d["kind"], name=d["name"],
                   t=float(d["t"]), dur_s=float(d.get("dur_s", 0.0)),
                   complete_s=float(d.get("complete_s", 0.0)),
                   payload_bytes=int(d.get("payload_bytes", 0)),
                   meta=dict(d.get("meta", {})))

    def describe(self) -> str:
        """One fixed-width timeline line (Listing-1 style)."""
        extra = ""
        if self.payload_bytes:
            extra += f" payload={self.payload_bytes}B"
        if self.complete_s:
            extra += f" complete={self.complete_s*1e6:.1f}us"
        for k in ("mode", "chain_len", "doorbells", "command_bytes",
                  "payload"):
            if k in self.meta:
                extra += f" {k}={self.meta[k]}"
        return (f"{self.seq:>6d}  {self.t*1e3:>10.3f}ms  {self.kind:<12s} "
                f"{self.name:<28s} dur={self.dur_s*1e6:>9.1f}us{extra}")


class RingBufferSink:
    """Bounded in-memory event store (drops oldest beyond ``maxlen``).

    Thread-safe: a ring shared across sessions (each serializing its own
    ``emit`` under its own lock) still counts ``n_emitted``/``dropped``
    exactly, and snapshot reads never observe a half-applied append.
    """

    def __init__(self, maxlen: int = 4096) -> None:
        self.maxlen = int(maxlen)
        self._buf: collections.deque = collections.deque(maxlen=self.maxlen)
        self._lock = threading.Lock()
        self._n_emitted = 0         # total ever seen, incl. dropped

    def emit(self, event: TraceEvent) -> None:
        with self._lock:
            self._buf.append(event)
            self._n_emitted += 1

    @property
    def n_emitted(self) -> int:
        with self._lock:
            return self._n_emitted

    @property
    def dropped(self) -> int:
        with self._lock:
            return self._n_emitted - len(self._buf)

    def events(self) -> List[TraceEvent]:
        with self._lock:
            return list(self._buf)

    def __len__(self) -> int:
        with self._lock:
            return len(self._buf)

    def __iter__(self) -> Iterator[TraceEvent]:
        return iter(self.events())

    def close(self) -> None:  # sink protocol
        pass

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {"sink": "RingBufferSink", "maxlen": self.maxlen,
                    "emitted": self._n_emitted,
                    "dropped": self._n_emitted - len(self._buf)}


class JsonlSink:
    """Append-only JSONL file sink; one event per line.

    The file is opened lazily on first emit so constructing a session with a
    ``jsonl_path`` is free until something is actually traced.  The lazy open
    and every write/flush/close run under one internal lock: a sink shared by
    several sessions (or hit from a traffic-generator thread while the decode
    loop emits) never double-opens the file or interleaves partial lines.
    """

    def __init__(self, path: str) -> None:
        self.path = str(path)
        self._fh: Optional[IO[str]] = None
        self._lock = threading.Lock()
        self._n_written = 0

    def emit(self, event: TraceEvent) -> None:
        line = json.dumps(event.to_dict()) + "\n"
        with self._lock:
            if self._fh is None:
                self._fh = open(self.path, "a")
            self._fh.write(line)
            self._n_written += 1

    def flush(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.flush()

    def close(self) -> None:
        with self._lock:
            if self._fh is not None:
                self._fh.flush()
                self._fh.close()
                self._fh = None

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {"sink": "JsonlSink", "path": self.path,
                    "written": self._n_written}

    @staticmethod
    def load(path: str) -> List[TraceEvent]:
        """Read a JSONL trace back into events (round-trip helper).

        A malformed *final* line is skipped with a warning instead of
        raising: a process killed mid-write leaves a truncated last line,
        and :mod:`repro.obs.aggregate` must still merge the shards of dead
        processes.  Corruption anywhere earlier still raises — that is not
        a crash artifact but a broken file.
        """
        with open(path) as f:
            lines = f.read().splitlines()
        out: List[TraceEvent] = []
        for i, line in enumerate(lines):
            line = line.strip()
            if not line:
                continue
            try:
                out.append(TraceEvent.from_dict(json.loads(line)))
            except (json.JSONDecodeError, KeyError, TypeError, ValueError):
                if any(l.strip() for l in lines[i + 1:]):
                    raise
                warnings.warn(
                    f"{path}: skipping truncated trailing line "
                    f"({len(line)} chars) — partial write from a "
                    f"crashed/killed process", RuntimeWarning)
                break
        return out


_current: contextvars.ContextVar = contextvars.ContextVar(
    "repro_trace_session", default=None)


def current_session() -> Optional["TraceSession"]:
    """The ambient session installed by ``with TraceSession(...)`` (or None)."""
    return _current.get()


@dataclasses.dataclass(frozen=True)
class SpanFrame:
    """Immutable identity of one span: its place in the causal tree.

    ``ids`` is the full ancestor chain ending at this span (so the root
    request a deeply nested doorbell belongs to is recoverable from the
    stamped event alone, with no ordering assumptions); ``path`` is the
    matching ``/``-joined name chain, the aggregation key
    :class:`~repro.obs.profile.SpanProfile` reports by.
    """

    span_id: int
    parent_id: Optional[int]
    name: str
    path: str                   # "request/decode_iter"
    ids: Tuple[int, ...]        # ancestor span_ids, self last

    def stamp(self) -> Dict[str, Any]:
        """The meta keys stamped onto every event emitted under this span."""
        return {"span": self.name, "span_id": self.span_id,
                "parent_span_id": self.parent_id, "span_path": self.path,
                "span_ids": list(self.ids)}


class SpanHandle:
    """One open span; :meth:`end` emits its ``obs.span`` close event.

    Handles exist so *logical* spans that cannot be a lexical ``with``
    block — a serve request whose decode launches interleave with other
    requests' — can still be first-class spans: the owner keeps the handle,
    accumulates attribution (doorbell participations, payload bytes), and
    declares them at :meth:`end`.  Context-managed spans
    (:meth:`TraceSession.span`) are built on the same handle and close
    automatically.
    """

    def __init__(self, session: "TraceSession", frame: SpanFrame,
                 attrs: Dict[str, Any], t_start: float) -> None:
        self.session = session
        self.frame = frame
        self.attrs = dict(attrs)
        self.t_start = t_start          # absolute perf_counter reading
        self.scoped = False             # True when contextvar-installed
        self._done = False

    @property
    def span_id(self) -> int:
        return self.frame.span_id

    @property
    def name(self) -> str:
        return self.frame.name

    def end(self, **attrs: Any) -> Optional["TraceEvent"]:
        """Close the span (idempotent); extra ``attrs`` merge into — and on
        collision win over — the open-time attributes.

        Declared-attribution keys (``doorbells``, ``payload``,
        ``graph_launches``) are how an owner credits work that was shared
        with other spans (e.g. one vmapped decode launch serving many
        requests) to this span explicitly.
        """
        if self._done:
            return None
        self._done = True
        t_end = time.perf_counter()
        meta = {**self.frame.stamp(), "scoped": self.scoped,
                "thread": threading.get_ident(), **self.attrs, **attrs}
        # Stamped at *end* time: in a time-sorted merged timeline the close
        # must follow every event emitted inside the span, or consumers
        # (SpanProfile) would fold the span before crediting them.  Slice
        # start is recoverable as ``t - dur_s``.
        return self.session.emit("progress", SPAN_EVENT,
                                 dur_s=t_end - self.t_start,
                                 t=t_end, **meta)


def ambient_span(name: str, **attrs: Any):
    """Span on the ambient session — a no-op context when none is active.

    Lets library code (e.g. :func:`repro.runtime.steps.init_all`) declare
    causal structure unconditionally without forcing a session on callers.
    """
    sess = current_session()
    if sess is None:
        return contextlib.nullcontext(None)
    return sess.span(name, **attrs)


class TraceSession:
    """The single entry point for all command-stream instrumentation.

    Usage (ambient activation — instrumented paths report implicitly)::

        with TraceSession("train", jsonl_path="trace.jsonl") as sess:
            cs = sess.capture.lower_and_compile("step", step_fn, args=(...,))
            step = sess.wrap(compiled, "train_step")
            step(params, batch)                     # -> dispatch event
            sess.mover.put(np.zeros(1 << 20))       # -> transfer event
        print(sess.report())

    Or explicit injection, no context manager required::

        sess = TraceSession("bench")
        tracker = DoorbellTracker(session=sess)

    The session owns the shared clock (``t0``) and the monotonic sequence
    counter; :meth:`emit` is thread-safe so async checkpoint/data threads can
    report concurrently.
    """

    def __init__(self, name: str = "session",
                 sinks: Optional[Iterable[Any]] = None,
                 ring_size: int = 4096,
                 jsonl_path: Optional[str] = None,
                 tags: Optional[Dict[str, Any]] = None) -> None:
        self.name = name
        #: Origin tags merged into every emitted event's ``meta`` (explicit
        #: per-event meta wins on key collision).  Fleet launchers set
        #: ``tags=distributed.context.process_tags()`` so per-process JSONL
        #: shards identify themselves to :mod:`repro.obs.aggregate`.
        self.tags: Dict[str, Any] = dict(tags or {})
        self.t0 = time.perf_counter()
        self.t0_wall = time.time()
        self._seq = 0
        self._span_seq = 0
        # The active span stack is contextvar-scoped: each thread (and each
        # asyncio task) sees only the spans it opened itself, so a traffic
        # thread's submits are never mis-attributed to the decode loop's
        # iteration span.  Per-instance so two sessions never share a stack.
        self._span_var: contextvars.ContextVar = contextvars.ContextVar(
            f"repro_span_{id(self)}", default=None)
        self._lock = threading.Lock()
        # Accounting accumulated at emit time, NOT derived from the ring —
        # summary() stays exact even after the bounded ring drops events.
        self._by_kind: Dict[str, int] = {}
        self._by_name: Dict[str, Dict[str, Any]] = {}
        self._kind_dur_s: Dict[str, float] = {}
        self._kind_payload: Dict[str, int] = {}
        self._total_payload = 0
        self._dispatch_s = 0.0
        self.ring = RingBufferSink(ring_size)
        self.sinks: List[Any] = [self.ring]
        if jsonl_path is not None:
            self.sinks.append(JsonlSink(jsonl_path))
        if sinks:
            self.sinks.extend(sinks)
        self._tokens: List[contextvars.Token] = []

        # Bound subsystem facades — one session drives everything.  Imported
        # lazily to avoid an import cycle (those modules import this one).
        from .capture import CommandStreamCapture
        from .dma import HybridMover
        from .doorbell import DoorbellTracker
        from .semaphore import ProgressTracker
        self.capture = CommandStreamCapture(session=self)
        self.doorbell = DoorbellTracker(session=self)
        self.mover = HybridMover(session=self)
        self.progress = ProgressTracker(session=self)

    # -- activation --------------------------------------------------------
    def __enter__(self) -> "TraceSession":
        self._tokens.append(_current.set(self))
        return self

    def __exit__(self, *exc: Any) -> None:
        _current.reset(self._tokens.pop())
        if not self._tokens:            # outermost exit: flush file sinks
            self.close()

    def close(self) -> None:
        for s in list(self.sinks):
            close = getattr(s, "close", None)
            if close is not None:
                close()

    # -- sink management ----------------------------------------------------
    def add_sink(self, sink: Any) -> Any:
        """Attach a sink mid-flight (thread-safe w.r.t. concurrent emits)."""
        with self._lock:
            self.sinks = self.sinks + [sink]    # swap, never mutate in place
        return sink

    def remove_sink(self, sink: Any) -> None:
        with self._lock:
            self.sinks = [s for s in self.sinks if s is not sink]

    def flush(self) -> None:
        """Flush every sink that supports it (e.g. before aggregation)."""
        for s in list(self.sinks):
            flush = getattr(s, "flush", None)
            if flush is not None:
                flush()

    def sink_stats(self) -> List[Dict[str, Any]]:
        """Per-sink loss/throughput accounting (JSON-serializable).

        Sinks without a ``stats()`` method report just their type name, so
        the list always has one entry per installed sink.
        """
        out: List[Dict[str, Any]] = []
        for s in list(self.sinks):
            stats = getattr(s, "stats", None)
            out.append(stats() if stats is not None
                       else {"sink": type(s).__name__})
        return out

    # -- spans (causal attribution) ----------------------------------------
    def current_span(self) -> Optional[SpanFrame]:
        """The innermost span active in *this* context (or None)."""
        return self._span_var.get()

    def start_span(self, name: str, parent: Optional[SpanFrame] = None,
                   **attrs: Any) -> SpanHandle:
        """Open a span *without* installing it as ambient context.

        The returned handle must be closed with ``handle.end(**attrs)``.
        ``parent`` defaults to the caller's current ambient span, so manual
        spans still slot into the causal tree.  Use :meth:`span` for the
        common lexically-scoped case — manual handles are for spans whose
        lifetime crosses scheduler iterations (a serve request).
        """
        if parent is None:
            parent = self._span_var.get()
        with self._lock:
            sid = self._span_seq
            self._span_seq += 1
        if parent is None:
            frame = SpanFrame(span_id=sid, parent_id=None, name=name,
                              path=name, ids=(sid,))
        else:
            frame = SpanFrame(span_id=sid, parent_id=parent.span_id,
                              name=name, path=f"{parent.path}/{name}",
                              ids=parent.ids + (sid,))
        return SpanHandle(self, frame, attrs, time.perf_counter())

    @contextlib.contextmanager
    def span(self, name: str, **attrs: Any) -> Iterator[SpanHandle]:
        """Nestable causal span: every event emitted in this context (and
        thread) is stamped with the span's identity.

        ::

            with sess.span("request", uid=7):
                prefill(...)                    # dispatch -> span-stamped
                with sess.span("decode_iter"):  # nested child span
                    decode(...)

        Exiting emits the ``obs.span`` close event (``t`` = span start,
        ``dur_s`` = span wall time) carrying ``attrs``.  Contextvar scoping
        makes concurrent threads' spans invisible to each other.
        """
        handle = self.start_span(name, **attrs)
        handle.scoped = True
        token = self._span_var.set(handle.frame)
        try:
            yield handle
        finally:
            self._span_var.reset(token)
            handle.end()

    # -- emission ----------------------------------------------------------
    def emit(self, kind: str, name: str,
             dur_s: float = 0.0, complete_s: float = 0.0,
             payload_bytes: int = 0, t: Optional[float] = None,
             **meta: Any) -> TraceEvent:
        """Record one event; returns it with its assigned sequence number.

        ``t`` is an absolute ``perf_counter`` reading (defaults to now) and
        is rebased onto the session clock.
        """
        if kind not in EVENT_KINDS:
            raise ValueError(f"unknown event kind {kind!r}; "
                             f"expected one of {EVENT_KINDS}")
        t_abs = time.perf_counter() if t is None else t
        # Attribution stamping: tags < active span < explicit meta.  A
        # span-close event carries its *own* identity explicitly, so the
        # (by then parent) ambient frame never overwrites it.
        frame = self._span_var.get()
        if frame is not None:
            meta = {**frame.stamp(), **meta}
        if self.tags:
            meta = {**self.tags, **meta}        # explicit meta wins
        # The whole emit is one critical section: sequence assignment,
        # accounting, and sink fan-out (lazy file opens, ring pushes) must
        # not interleave across threads.
        with self._lock:
            seq = self._seq
            self._seq += 1
            ev = TraceEvent(seq=seq, kind=kind, name=name,
                            t=t_abs - self.t0, dur_s=dur_s,
                            complete_s=complete_s,
                            payload_bytes=payload_bytes, meta=meta)
            self._by_kind[kind] = self._by_kind.get(kind, 0) + 1
            self._kind_dur_s[kind] = self._kind_dur_s.get(kind, 0.0) + dur_s
            self._kind_payload[kind] = (self._kind_payload.get(kind, 0)
                                        + payload_bytes)
            d = self._by_name.setdefault(name, {"events": 0, "dur_s": 0.0,
                                                "payload_bytes": 0})
            d["events"] += 1
            d["dur_s"] += dur_s
            d["payload_bytes"] += payload_bytes
            self._total_payload += payload_bytes
            if kind == "dispatch":
                self._dispatch_s += dur_s
            for s in self.sinks:
                s.emit(ev)
        return ev

    def barrier(self, barrier_id: str, wall: Optional[float] = None
                ) -> TraceEvent:
        """Emit a clock-alignment barrier event (name ``obs.barrier``).

        Every process of a fleet emits a barrier with the *same*
        ``barrier_id`` at (approximately) the same real moment — e.g. right
        after a collective, or at mesh setup.  Each barrier records the
        process-local session clock *and* a wall-clock reading, giving
        :mod:`repro.obs.aggregate` two independent ways to solve for the
        per-shard clock offset when merging JSONL shards.
        """
        return self.emit("progress", BARRIER_EVENT,
                         barrier=str(barrier_id),
                         wall=time.time() if wall is None else wall)

    # -- convenience wrappers (delegate to bound facades) ------------------
    def wrap(self, fn: Callable, name: str = "dispatch",
             block: bool = False) -> Callable:
        """Doorbell-wrap a callable; each call lands a ``dispatch`` event."""
        return self.doorbell.wrap(fn, name=name, block=block)

    def lower_and_compile(self, name: str, fn: Callable, **kw: Any):
        """Capture a lower/compile through the bound capture facade."""
        return self.capture.lower_and_compile(name, fn, **kw)

    def put(self, x: Any):
        """Move data through the bound :class:`HybridMover`."""
        return self.mover.put(x)

    # -- querying ----------------------------------------------------------
    @property
    def n_events(self) -> int:
        return self.ring.n_emitted

    def timeline(self, kinds: Optional[Iterable[str]] = None,
                 name: Optional[str] = None) -> List[TraceEvent]:
        """Events in submission order (monotonic ``seq``), optionally
        filtered by kind(s) and/or name."""
        evs = self.ring.events()
        if kinds is not None:
            ks = {kinds} if isinstance(kinds, str) else set(kinds)
            evs = [e for e in evs if e.kind in ks]
        if name is not None:
            evs = [e for e in evs if e.name == name]
        return sorted(evs, key=lambda e: e.seq)

    def summary(self) -> Dict[str, Any]:
        """JSON-serializable per-kind/per-name accounting.

        Counts come from emit-time accumulators (exact over the whole run);
        only ``timeline()`` is bounded by the ring.  ``total_dispatch_s``
        sums host dispatch time over ``dispatch`` events only — compile and
        transfer durations live under their names in ``by_name``.

        The schema is fixed whether or not anything was traced.  Keys:
        ``session`` (name), ``events`` (total emitted), ``dropped`` (ring
        overflow), ``by_kind`` / ``dur_s_by_kind`` / ``payload_by_kind``
        (per-kind counts / host seconds / payload bytes), ``by_name``
        (per-label ``{events, dur_s, payload_bytes}``),
        ``total_payload_bytes``, ``total_dispatch_s``, and ``wall_s``.  An
        *empty* session returns this exact shape zeroed — per-kind maps
        carry every kind in :data:`EVENT_KINDS` at 0 — so downstream
        consumers (live endpoints, BENCH artifacts, aggregation) never
        special-case "nothing happened yet".
        """
        with self._lock:
            n = self._seq
            by_kind = dict(self._by_kind)
            by_name = {k: dict(v) for k, v in self._by_name.items()}
            kind_dur = dict(self._kind_dur_s)
            kind_payload = dict(self._kind_payload)
            payload = self._total_payload
            dispatch_s = self._dispatch_s
        if n == 0:
            by_kind = {k: 0 for k in EVENT_KINDS}
            kind_dur = {k: 0.0 for k in EVENT_KINDS}
            kind_payload = {k: 0 for k in EVENT_KINDS}
        return {
            "session": self.name,
            "events": self.ring.n_emitted,
            "dropped": self.ring.dropped,
            "by_kind": by_kind,
            "dur_s_by_kind": kind_dur,
            "payload_by_kind": kind_payload,
            "by_name": by_name,
            "total_payload_bytes": payload,
            "total_dispatch_s": dispatch_s,
            "wall_s": time.perf_counter() - self.t0,
        }

    def report(self, max_events: int = 60,
               kinds: Optional[Iterable[str]] = None) -> str:
        """Listing-1-style interleaved timeline: every subsystem's events in
        one submission-ordered view."""
        evs = self.timeline(kinds=kinds)
        s = self.summary()
        lines = [f"==== TRACE SESSION {self.name} ===="]
        lines.append("  ".join(f"{k}={v}" for k, v in s["by_kind"].items())
                     or "  (no events)")
        lines.append(f"events={s['events']} dropped={s['dropped']} "
                     f"payload={s['total_payload_bytes']}B "
                     f"wall={s['wall_s']:.3f}s")
        lines.append(f"{'seq':>6s}  {'t':>12s}  {'kind':<12s} "
                     f"{'name':<28s} host-cost")
        for e in evs[:max_events]:
            lines.append(e.describe())
        if len(evs) > max_events:
            lines.append(f"  ... {len(evs) - max_events} more")
        lines.append(f"==== END TRACE SESSION {self.name} ====")
        return "\n".join(lines)


def resolve_session(explicit: Optional[TraceSession]) -> Optional[TraceSession]:
    """Explicit injection wins; otherwise fall back to the ambient session.

    Instrumented primitives call this *at emission time* so a tracker built
    before ``with TraceSession(...)`` still reports while the block is
    active — the watchpoint sees everything, whenever it was armed.
    """
    return explicit if explicit is not None else current_session()
