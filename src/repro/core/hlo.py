"""HLO command-stream parser — the framework's analogue of the paper's
pushbuffer reconstruction (Listing 1).

The paper reconstructs NVIDIA pushbuffer command streams by walking from the
doorbell write back through the GPFIFO entry to the pushbuffer, then decoding
each method against the open-source headers.  On the JAX/XLA stack the
"pushbuffer" is the compiled HLO module: the instruction stream the device
actually consumes.  This module decodes ``compiled.as_text()`` into structured
:class:`CommandEntry` records and aggregates what the rest of the framework
needs:

* **trip-count-aware totals** — XLA's ``cost_analysis()`` visits a ``while``
  body once, so a model that scans over L layers under-reports FLOPs by L×.
  We recover ``known_trip_count`` from backend_config and weight every
  instruction by its execution multiplier;
* **collective traffic** (all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute) with op-aware ring link-byte accounting,
  for the roofline collective term;
* **command footprint** (serialized size + op count) — the quantity the
  paper's CUDA-Graph case study shows is the precursor of launch overhead;
* **engine classification** (MXU-compute / HBM / ICI-collective / host),
  the analogue of the paper's compute-engine vs copy-engine split.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

__all__ = [
    "CommandEntry",
    "CommandStream",
    "parse_hlo",
    "dtype_bytes",
    "COLLECTIVE_OPS",
]

_DTYPE_BYTES: Dict[str, float] = {
    "pred": 1, "s2": 0.25, "s4": 0.5, "s8": 1, "s16": 2, "s32": 4, "s64": 8,
    "u2": 0.25, "u4": 0.5, "u8": 1, "u16": 2, "u32": 4, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3": 1,
    "f8e5m2fnuz": 1, "f8e4m3fnuz": 1, "f8e3m4": 1, "f8e8m0fnu": 1,
    "f4e2m1fn": 0.5, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
    "collective-broadcast",
    "ragged-all-to-all",
)

_COMPUTE_OPS = ("dot", "convolution", "cholesky", "triangular-solve", "fft")
_FREE_OPS = ("parameter", "get-tuple-element", "tuple", "bitcast",
             "after-all", "opt-barrier")
_ELEMENTWISE_OPS = (
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "tanh", "exponential", "log", "rsqrt", "sqrt", "negate", "abs", "floor",
    "ceil", "sign", "cosine", "sine", "logistic", "expm1", "log1p", "erf",
    "atan2", "remainder", "cbrt", "round-nearest-afz", "round-nearest-even",
)

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_COMP_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->\s+.*\{\s*$")
_INSTR_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=")
_OPCODE_RE = re.compile(
    r"=\s*(?:\([^()]*\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"([a-z][a-z0-9\-]*)\s*\(")
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"\bcalls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"\bbody=%?([\w.\-]+)")
_COND_RE = re.compile(r"\bcondition=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"\bto_apply=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[([0-9,]+)\]<=\[")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OP_NAME_RE = re.compile(r'op_name="([^"]+)"')
_FEATURE_GROUP_RE = re.compile(r"feature_group_count=(\d+)")


def dtype_bytes(dtype: str) -> float:
    return _DTYPE_BYTES.get(dtype, 4)


def _dims(dim_str: str) -> Tuple[int, ...]:
    if not dim_str.strip():
        return ()
    return tuple(int(d) for d in dim_str.split(","))


def _prod(xs) -> int:
    n = 1
    for x in xs:
        n *= x
    return n


@dataclasses.dataclass
class _Shape:
    dtype: str
    dims: Tuple[int, ...]

    @property
    def nbytes(self) -> int:
        return int(_prod(self.dims) * dtype_bytes(self.dtype))

    @property
    def nelems(self) -> int:
        return _prod(self.dims)


def _parse_shapes(text: str) -> List[_Shape]:
    return [_Shape(d, _dims(dims)) for d, dims in _SHAPE_RE.findall(text)]


@dataclasses.dataclass
class _Instr:
    name: str
    opcode: str
    result_shapes: List[_Shape]
    operand_names: List[str]
    line: str

    @property
    def result_bytes(self) -> int:
        return sum(s.nbytes for s in self.result_shapes)


@dataclasses.dataclass
class _Computation:
    name: str
    is_entry: bool
    params: Dict[str, List[_Shape]]
    instrs: List[_Instr]
    symbols: Dict[str, List[_Shape]]


def _classify(opcode: str) -> str:
    for c in COLLECTIVE_OPS:
        if opcode.startswith(c):
            return "collective"
    for c in _COMPUTE_OPS:
        if opcode.startswith(c):
            return "compute"
    if opcode in ("fusion", "call", "while", "conditional"):
        return "control"
    if opcode.startswith(("infeed", "outfeed", "send", "recv")):
        return "host"
    if opcode in ("copy", "copy-start", "copy-done", "dynamic-update-slice",
                  "dynamic-slice", "gather", "scatter", "transpose", "reshape",
                  "broadcast", "slice", "concatenate", "pad", "reverse",
                  "iota", "constant"):
        return "transfer"
    return "other"


def _group_size(line: str) -> int:
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip() != ""]
        return max(1, len(ids))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        dims = [int(x) for x in m.group(1).split(",")]
        if len(dims) >= 2:
            return max(1, _prod(dims[1:]))
        return max(1, dims[0])
    return 1


def _link_bytes(opcode: str, result_b: int, operand_b: int, n: int) -> int:
    """Per-device ICI bytes for a ring realization of the collective."""
    if n <= 1:
        return 0
    frac = (n - 1) / n
    if opcode.startswith("all-gather"):
        # async '-start' ops carry (operand, result) tuples; recover the
        # gathered buffer size before applying the ring fraction.
        gathered = result_b - operand_b if opcode.endswith("-start") else result_b
        return int(max(gathered, operand_b) * frac)
    if opcode.startswith("reduce-scatter"):
        return int(operand_b * frac)
    if opcode.startswith("all-reduce"):
        return int(2 * operand_b * frac)
    if opcode.startswith(("all-to-all", "ragged-all-to-all")):
        return int(operand_b * frac)
    if opcode.startswith(("collective-permute", "collective-broadcast")):
        return int(operand_b)
    return int(operand_b * frac)


@dataclasses.dataclass
class CommandEntry:
    """One decoded executed instruction — one parsed "pushbuffer method"."""

    index: int
    name: str
    opcode: str
    computation: str
    multiplier: int            # execution count (trip-count product)
    result_bytes: int
    operand_bytes: int
    engine: str                # compute | collective | transfer | control | host | other
    flops: int = 0             # per single execution
    group_size: int = 1
    link_bytes: int = 0        # per single execution, per-device ICI bytes
    op_path: str = ""          # jax-level op_name metadata (model attribution)
    raw: str = ""

    def describe(self) -> str:
        extra = ""
        if self.engine == "collective":
            extra = f" groups={self.group_size} link_bytes={self.link_bytes}"
        if self.flops:
            extra += f" flops={self.flops}"
        mult = f" x{self.multiplier}" if self.multiplier != 1 else ""
        return (f"CS[{self.index:>4d}] {self.opcode:<22s} {self.engine:<10s}"
                f" out={self.result_bytes}B in={self.operand_bytes}B{extra}{mult}")


@dataclasses.dataclass
class CommandStream:
    """A fully decoded command stream (one compiled submission unit)."""

    entries: List[CommandEntry]
    text_bytes: int
    n_ops: int
    unknown_trip_counts: bool = False

    # ---- aggregates (all trip-count weighted) ---------------------------
    @property
    def total_flops(self) -> int:
        return sum(e.flops * e.multiplier for e in self.entries)

    @property
    def memory_bytes(self) -> int:
        """HBM-traffic proxy: operand+result bytes of every executed
        top-level instruction (post-fusion boundaries are real memory
        boundaries)."""
        return sum((e.result_bytes + e.operand_bytes) * e.multiplier
                   for e in self.entries
                   if e.engine not in ("control",) or e.opcode == "fusion")

    @property
    def collective_entries(self) -> List[CommandEntry]:
        return [e for e in self.entries if e.engine == "collective"]

    @property
    def collective_link_bytes(self) -> int:
        return sum(e.link_bytes * e.multiplier for e in self.collective_entries)

    def collective_bytes_by_op(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.collective_entries:
            key = e.opcode.replace("-start", "").replace("-done", "")
            out[key] = out.get(key, 0) + e.link_bytes * e.multiplier
        return out

    def collective_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.collective_entries:
            if e.opcode.endswith("-done"):
                continue
            key = e.opcode.replace("-start", "")
            out[key] = out.get(key, 0) + e.multiplier
        return out

    def counts_by_engine(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.entries:
            out[e.engine] = out.get(e.engine, 0) + 1
        return out

    def counts_by_opcode(self, top: int = 0) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for e in self.entries:
            out[e.opcode] = out.get(e.opcode, 0) + 1
        if top:
            out = dict(sorted(out.items(), key=lambda kv: -kv[1])[:top])
        return out

    def summary(self) -> Dict[str, object]:
        return {
            "n_ops": self.n_ops,
            "command_bytes": self.text_bytes,
            "flops": self.total_flops,
            "memory_bytes": self.memory_bytes,
            "collective_link_bytes": self.collective_link_bytes,
            "collectives": self.collective_bytes_by_op(),
            "collective_counts": self.collective_counts(),
            "unknown_trip_counts": self.unknown_trip_counts,
        }


def _split_computations(text: str) -> List[_Computation]:
    comps: List[_Computation] = []
    cur: Optional[_Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HEADER_RE.match(stripped)
            if m:
                header = stripped
                params: Dict[str, List[_Shape]] = {}
                # signature: (name: shape, name: (tuple, shapes), ...)
                sig = header[header.find("(") + 1:header.rfind("->")]
                for pm in re.finditer(r"([\w.\-]+):\s*(\([^)]*\)|[a-z][a-z0-9]*\[[0-9,]*\])", sig):
                    params[pm.group(1)] = _parse_shapes(pm.group(2))
                cur = _Computation(
                    name=m.group(2), is_entry=bool(m.group(1)),
                    params=params, instrs=[],
                    symbols={k: v for k, v in params.items()})
            continue
        if stripped == "}":
            comps.append(cur)
            cur = None
            continue
        nm = _INSTR_NAME_RE.match(line)
        if not nm or "=" not in stripped:
            continue
        om = _OPCODE_RE.search(stripped)
        if not om:
            continue
        opcode = om.group(1)
        name = nm.group(1)
        eq = stripped.index("=")
        op_pos = stripped.find(opcode + "(", eq)
        head = stripped[eq:op_pos] if op_pos > 0 else stripped[eq:]
        tail = stripped[op_pos:stripped.find(")", op_pos) + 1] if op_pos > 0 else ""
        result_shapes = _parse_shapes(head)
        operand_names = _OPERAND_NAME_RE.findall(tail)
        instr = _Instr(name=name, opcode=opcode, result_shapes=result_shapes,
                       operand_names=operand_names, line=stripped)
        cur.instrs.append(instr)
        cur.symbols[name] = result_shapes
    return comps


def _operand_bytes(instr: _Instr, comp: _Computation) -> int:
    total = 0
    for nm in instr.operand_names:
        shapes = comp.symbols.get(nm)
        if shapes:
            total += sum(s.nbytes for s in shapes)
    return total


_PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)")


def _fusion_mem(comp: _Computation, operand_b: List[int], result_b: int
                ) -> Tuple[int, int]:
    """(read, write) HBM-byte estimate for a fusion call.

    Dynamic-slice reads and dynamic-update-slice writes fused into a body
    touch only the slice, not the full (often [L, ...] scan-stacked) buffer
    — counting full operands over-counts memory traffic by O(L) per step
    and O(L²) per scan.  Parameters consumed *only* by DS/DUS are therefore
    charged at slice size; an in-place DUS accumulator charges the update
    size as the write.
    """
    reads = list(operand_b)
    writes = result_b
    param_idx: Dict[str, int] = {}
    for ins in comp.instrs:
        if ins.opcode == "parameter":
            m = _PARAM_IDX_RE.search(ins.line)
            if m:
                param_idx[ins.name] = int(m.group(1))
    uses: Dict[str, List[_Instr]] = {}
    for ins in comp.instrs:
        if ins.opcode == "parameter":
            continue
        for nm in set(ins.operand_names):
            uses.setdefault(nm, []).append(ins)

    _UNARY = ("convert", "bitcast", "copy", "reshape", "transpose")

    def chase(nm: str, hops: int = 4) -> Tuple[str, List[_Instr]]:
        """Follow single-use unary chains (convert/bitcast/...) from nm."""
        us = uses.get(nm, [])
        while hops and len(us) == 1 and us[0].opcode in _UNARY:
            nm = us[0].name
            us = uses.get(nm, [])
            hops -= 1
        return nm, us

    for nm, idx in param_idx.items():
        if idx >= len(reads):
            continue
        eff, us = chase(nm)
        if not us:
            continue
        if all(u.opcode == "dynamic-slice" and u.operand_names
               and u.operand_names[0] == eff for u in us):
            reads[idx] = sum(u.result_bytes for u in us)
        elif all(u.opcode == "dynamic-update-slice" and u.operand_names
                 and u.operand_names[0] == eff for u in us):
            upd = 0
            for u in us:
                if len(u.operand_names) > 1:
                    upd += sum(s.nbytes for s in
                               comp.symbols.get(u.operand_names[1], []))
            reads[idx] = upd
            if operand_b[idx] == result_b or \
                    abs(operand_b[idx] - result_b) <= result_b // 2:
                writes = max(upd, 1)  # in-place accumulator
    return sum(reads), writes


def _dot_flops(instr: _Instr, comp: _Computation) -> int:
    out = sum(s.nelems for s in instr.result_shapes)
    m = _LHS_CONTRACT_RE.search(instr.line)
    contract = 1
    if m and instr.operand_names:
        lhs = comp.symbols.get(instr.operand_names[0])
        if lhs and m.group(1).strip():
            dims = lhs[0].dims
            for ci in m.group(1).split(","):
                ci = int(ci)
                if ci < len(dims):
                    contract *= dims[ci]
    return 2 * out * contract


def _conv_flops(instr: _Instr, comp: _Computation) -> int:
    out = sum(s.nelems for s in instr.result_shapes)
    kern_elems = 0
    if len(instr.operand_names) >= 2:
        k = comp.symbols.get(instr.operand_names[1])
        if k:
            kern_elems = k[0].nelems
    fg = 1
    m = _FEATURE_GROUP_RE.search(instr.line)
    if m:
        fg = int(m.group(1))
    # per output element: 2 * (kernel elems per output channel)
    out_ch = max(1, instr.result_shapes[0].dims[-1] if instr.result_shapes[0].dims else 1)
    per_out = max(1, kern_elems // max(1, out_ch)) if kern_elems else 1
    del fg
    return 2 * out * per_out


def parse_hlo(text: str) -> CommandStream:
    """Decode an HLO module dump into a :class:`CommandStream`.

    Use on ``compiled.as_text()`` (post-SPMD, per-device shapes, scheduled).
    Collectives, FLOPs and memory bytes are weighted by ``known_trip_count``
    execution multipliers so scanned (``lax.scan``) layer stacks are counted
    correctly — XLA's own ``cost_analysis`` does not do this.
    """
    comps = {c.name: c for c in _split_computations(text)}
    entry = next((c for c in comps.values() if c.is_entry), None)
    entries: List[CommandEntry] = []
    unknown_trips = False
    idx = 0

    def fusion_flops(comp: _Computation, mult: int, seen: set) -> int:
        """FLOPs contributed by instructions inside a fusion/call body."""
        if comp.name in seen:
            return 0
        seen.add(comp.name)
        fl = 0
        for ins in comp.instrs:
            if ins.opcode == "dot":
                fl += _dot_flops(ins, comp)
            elif ins.opcode == "convolution":
                fl += _conv_flops(ins, comp)
            elif ins.opcode in _ELEMENTWISE_OPS or ins.opcode in ("compare", "select", "clamp"):
                fl += sum(s.nelems for s in ins.result_shapes)
            elif ins.opcode in ("reduce", "reduce-window"):
                fl += sum(sum(s.nelems for s in comp.symbols.get(nm, []))
                          for nm in ins.operand_names[:1])
            cm = _CALLS_RE.search(ins.line)
            if cm and cm.group(1) in comps:
                fl += fusion_flops(comps[cm.group(1)], 1, seen)
        return fl

    def walk(comp: _Computation, mult: int, depth: int = 0):
        nonlocal idx, unknown_trips
        if depth > 32:
            return
        for ins in comp.instrs:
            if ins.opcode in _FREE_OPS or ins.opcode == "constant":
                continue
            if ins.opcode == "while":
                tm = _TRIP_RE.search(ins.line)
                trips = int(tm.group(1)) if tm else 1
                if not tm:
                    unknown_trips = True
                bm = _BODY_RE.search(ins.line)
                cm_ = _COND_RE.search(ins.line)
                if bm and bm.group(1) in comps:
                    walk(comps[bm.group(1)], mult * trips, depth + 1)
                if cm_ and cm_.group(1) in comps:
                    # condition is cheap; count once per trip for op stats
                    pass
                continue
            if ins.opcode == "conditional":
                bm = _BRANCHES_RE.search(ins.line)
                if bm:
                    for bname in _OPERAND_NAME_RE.findall(bm.group(1)):
                        if bname in comps:
                            walk(comps[bname], mult, depth + 1)
                continue
            if ins.opcode == "call":
                cm = _CALLS_RE.search(ins.line) or _TO_APPLY_RE.search(ins.line)
                if cm and cm.group(1) in comps:
                    walk(comps[cm.group(1)], mult, depth + 1)
                continue

            opr_b = _operand_bytes(ins, comp)
            res_b = ins.result_bytes
            engine = _classify(ins.opcode)
            flops = 0
            if ins.opcode == "dot":
                flops = _dot_flops(ins, comp)
            elif ins.opcode == "convolution":
                flops = _conv_flops(ins, comp)
            elif ins.opcode == "dynamic-slice":
                # in-place read of just the slice
                opr_b = res_b
            elif ins.opcode == "dynamic-update-slice":
                upd = (sum(s.nbytes for s in
                           comp.symbols.get(ins.operand_names[1], []))
                       if len(ins.operand_names) > 1 else res_b)
                opr_b = upd
                res_b = upd  # aliased in-place write
            elif ins.opcode == "fusion":
                cm = _CALLS_RE.search(ins.line)
                if cm and cm.group(1) in comps:
                    body = comps[cm.group(1)]
                    flops = fusion_flops(body, mult, set())
                    per_op = [sum(s.nbytes for s in comp.symbols.get(nm, []))
                              for nm in ins.operand_names]
                    opr_b, res_b = _fusion_mem(body, per_op, res_b)
                engine = "fusion"
            elif ins.opcode in _ELEMENTWISE_OPS or ins.opcode in ("compare", "select", "clamp"):
                flops = sum(s.nelems for s in ins.result_shapes)
            elif ins.opcode in ("reduce", "reduce-window", "sort"):
                flops = opr_b and sum(
                    sum(s.nelems for s in comp.symbols.get(nm, []))
                    for nm in ins.operand_names[:1]) or 0

            gs = 1
            lb = 0
            if engine == "collective":
                gs = _group_size(ins.line)
                if ins.opcode.endswith("-done"):
                    lb = 0
                else:
                    lb = _link_bytes(ins.opcode, res_b, opr_b, gs)
            opm = _OP_NAME_RE.search(ins.line)
            entries.append(CommandEntry(
                index=idx, name=ins.name, opcode=ins.opcode,
                computation=comp.name, multiplier=mult,
                result_bytes=res_b, operand_bytes=opr_b, engine=engine,
                flops=flops, group_size=gs, link_bytes=lb,
                op_path=opm.group(1) if opm else "", raw=ins.line[:240]))
            idx += 1

    if entry is not None:
        walk(entry, 1)
    return CommandStream(entries=entries, text_bytes=len(text),
                         n_ops=len(entries), unknown_trip_counts=unknown_trips)
