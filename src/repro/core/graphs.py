"""Execution graphs: launch modes and the command-footprint law.

The paper's second case study (§6.3) explains CUDA Graph launch scaling with
two submission-level indicators: the **command footprint** (bytes of commands
the host emits per launch) and the **number of submission cycles** (doorbell
writes).  CUDA 11.8 launches a K-kernel chain with K-ish doorbells and a
footprint linear in K (launch time 1.8 µs → 209 µs over K=1→2000); CUDA 13.0
uses one doorbell and a near-constant footprint (1.9 µs → 5.9 µs).

This module implements the same experiment — and the same *lesson* — on the
JAX stack with three launch modes for a chain of K nodes:

* ``per_op``   — one dispatch per node (≙ CUDA 11.8's many submission cycles);
* ``graphed``  — the chain is compiled into ONE executable, one dispatch, but
  the command footprint (HLO size) still grows with K (≙ CUDA 13.0);
* ``multistep``— the chain is rolled into a ``lax.scan``: one dispatch AND an
  O(1) command footprint (beyond-paper: the footprint law says this is the
  end point of the optimization the driver was making between 11.8 and 13.0).

The same machinery powers the Trainer's multi-step launcher: train K steps
per dispatch with O(1) footprint.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import hlo
from .doorbell import DoorbellTracker
from .session import TraceSession, resolve_session

__all__ = ["LaunchStats", "ExecGraph", "MultiStepLauncher", "LAUNCH_MODES"]

LAUNCH_MODES = ("per_op", "graphed", "multistep")


@dataclasses.dataclass
class LaunchStats:
    """The paper's three indicators for one launch."""

    mode: str
    chain_len: int
    doorbells: int             # submission cycles
    command_bytes: int         # footprint of the compiled stream(s)
    n_ops: int
    launch_s: float            # host wall time to submit (excl. completion)
    complete_s: float          # wall time to completion
    upload_s: float            # compile ("instantiate+upload") time, once


class ExecGraph:
    """A chain of K identical nodes ``x -> f(scale_k, x)``.

    Mirrors the paper's benchmark graph: a linear chain of identical small
    kernels (scalar multiply over an N-element array), issued to one stream.
    """

    def __init__(self, chain_len: int, width: int = 1024,
                 dtype=jnp.float32) -> None:
        self.chain_len = int(chain_len)
        self.width = int(width)
        self.dtype = dtype
        self.scales = jnp.linspace(1.0, 1.0 + 1e-6, chain_len).astype(dtype)
        # pre-staged per-node scale buffers: the per_op path must measure
        # dispatch cost, not host-side indexing
        self._scale_list = [self.scales[k] for k in range(chain_len)]
        self._compiled: Dict[str, Any] = {}
        self._upload_s: Dict[str, float] = {}

    # -- node ---------------------------------------------------------------
    @staticmethod
    def _node(scale: jax.Array, x: jax.Array) -> jax.Array:
        return x * scale

    def _x0(self) -> jax.Array:
        return jnp.ones((self.width,), self.dtype)

    # -- instantiate + upload (≙ cudaGraphInstantiate/Upload) ---------------
    def upload(self, mode: str) -> None:
        t0 = time.perf_counter()
        if mode == "per_op":
            lowered = jax.jit(self._node).lower(
                jax.ShapeDtypeStruct((), self.dtype),
                jax.ShapeDtypeStruct((self.width,), self.dtype))
            self._compiled[mode] = lowered.compile()
        elif mode == "graphed":
            # scales are runtime arguments so each node stays a distinct
            # command in the stream (XLA would constant-fold baked scalars,
            # which would defeat the footprint measurement)
            def chain(scales, x):
                for k in range(self.chain_len):
                    x = self._node(scales[k], x)
                return x

            lowered = jax.jit(chain).lower(
                tuple(jax.ShapeDtypeStruct((), self.dtype)
                      for _ in range(self.chain_len)),
                jax.ShapeDtypeStruct((self.width,), self.dtype))
            self._compiled[mode] = lowered.compile()
        elif mode == "multistep":
            def chain(scales, x):
                def body(c, s):
                    return self._node(s, c), ()
                y, _ = jax.lax.scan(body, x, scales)
                return y

            lowered = jax.jit(chain).lower(
                jax.ShapeDtypeStruct((self.chain_len,), self.dtype),
                jax.ShapeDtypeStruct((self.width,), self.dtype))
            self._compiled[mode] = lowered.compile()
        else:
            raise ValueError(f"unknown mode {mode!r}")
        self._upload_s[mode] = time.perf_counter() - t0

    def command_footprint(self, mode: str) -> Tuple[int, int]:
        """(bytes, ops) of command stream submitted per *launch*.

        per_op re-submits its (single-node) stream chain_len times — the
        total emitted per launch grows with K, like CUDA 11.8's per-kernel
        command emission.
        """
        compiled = self._compiled[mode]
        text = compiled.as_text()
        stream = hlo.parse_hlo(text)
        if mode == "per_op":
            return stream.text_bytes * self.chain_len, stream.n_ops * self.chain_len
        return stream.text_bytes, stream.n_ops

    # -- launch (≙ cudaGraphLaunch) ------------------------------------------
    def launch(self, mode: str, tracker: Optional[DoorbellTracker] = None,
               session: Optional[TraceSession] = None
               ) -> Tuple[jax.Array, LaunchStats]:
        if mode not in self._compiled:
            self.upload(mode)
        tracker = tracker or DoorbellTracker(session=session)
        compiled = self._compiled[mode]
        x = self._x0()
        jax.block_until_ready(x)
        cmd_bytes, n_ops = self.command_footprint(mode)

        scale_list = self._scale_list
        t0 = time.perf_counter()
        if mode == "per_op":
            y = x
            for k in range(self.chain_len):
                y = compiled(scale_list[k], y)
                tracker.ring("per_op_dispatch")
            t1 = time.perf_counter()
        elif mode == "graphed":
            y = compiled(tuple(scale_list), x)
            tracker.ring("graphed_dispatch")
            t1 = time.perf_counter()
        else:
            y = compiled(self.scales, x)
            tracker.ring("multistep_dispatch")
            t1 = time.perf_counter()
        jax.block_until_ready(y)
        t2 = time.perf_counter()

        doorbells = self.chain_len if mode == "per_op" else 1
        stats = LaunchStats(
            mode=mode, chain_len=self.chain_len, doorbells=doorbells,
            command_bytes=cmd_bytes, n_ops=n_ops,
            launch_s=t1 - t0, complete_s=t2 - t0,
            upload_s=self._upload_s.get(mode, 0.0))
        sess = resolve_session(session)
        if sess is not None:
            sess.emit("graph_launch", f"{mode}_launch", dur_s=stats.launch_s,
                      complete_s=stats.complete_s, t=t0, mode=mode,
                      chain_len=stats.chain_len, doorbells=stats.doorbells,
                      command_bytes=stats.command_bytes, n_ops=stats.n_ops)
        return y, stats

    def reference(self) -> jax.Array:
        """Oracle result of the chain."""
        x = self._x0()
        import numpy as np
        return x * np.prod(np.asarray(self.scales, dtype=np.float64)).astype(
            self.dtype)


class MultiStepLauncher:
    """Train/serve K steps per dispatch — the footprint lesson applied.

    Wraps a ``step(carry, batch) -> carry, aux`` function into a scanned
    K-step executable.  One doorbell submits K steps; the command footprint
    is O(1) in K.  This is the production feature distilled from the paper's
    CUDA-Graph case study.
    """

    def __init__(self, step_fn: Callable, k: int,
                 donate_carry: bool = True,
                 session: Optional[TraceSession] = None) -> None:
        self.k = int(k)
        self.step_fn = step_fn
        self._jitted = None
        self._session = session
        self.tracker = DoorbellTracker(session=session)

        def k_steps(carry, batches):
            def body(c, b):
                c, aux = step_fn(c, b)
                return c, aux
            return jax.lax.scan(body, carry, batches)

        self._k_steps = k_steps
        donate = (0,) if donate_carry else ()
        self._jitted = jax.jit(k_steps, donate_argnums=donate)

    def __call__(self, carry: Any, batches: Any) -> Tuple[Any, Any]:
        """``batches`` must be stacked along a leading K axis."""
        t0 = time.perf_counter()
        out = self._jitted(carry, batches)
        t1 = time.perf_counter()
        self.tracker.ring("multistep_launch")
        sess = resolve_session(self._session)
        if sess is not None:
            sess.emit("graph_launch", "multistep_launch", dur_s=t1 - t0,
                      t=t0, mode="multistep", chain_len=self.k, doorbells=1)
        return out

    def lower(self, carry_spec: Any, batches_spec: Any):
        return self._jitted.lower(carry_spec, batches_spec)
