"""Host→device data-movement protocols: inline vs direct.

The paper's first case study (§6.2) shows the NVIDIA driver silently selects
between two DMA submission modes for ``cudaMemcpy`` H2D:

* **inline DMA** (<24 KiB): the payload is embedded *in the command stream*
  and the compute engine materializes it at the destination — ~24 ns startup,
  saturating at ~17.5 GiB/s, rejected above 31 KiB;
* **direct DMA** (≥24 KiB): the command only carries src/dst descriptors and
  a dedicated copy engine moves the bytes — ~500 ns startup, 22 GiB/s.

CUDA exposes no control over the switch.  The paper's §7 contrasts this with
Open MPI, where protocol thresholds are exposed and tunable.  This module is
the TPU/JAX adaptation *with the tunable exposed*:

* **inline**: the operand is embedded as an XLA constant inside a compiled
  executable (it rides in the command stream / program, and the compute path
  materializes it on device);
* **direct**: an explicit ``jax.device_put`` transfer (the runtime's copy
  path carries the bytes, the program only references the buffer).

:class:`HybridMover` selects by size against an explicit, user-settable
threshold (default 24 KiB, mirroring the paper's observed switch point).
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .session import TraceSession, resolve_session

__all__ = [
    "INLINE_THRESHOLD_DEFAULT",
    "TransferRecord",
    "inline_put",
    "direct_put",
    "HybridMover",
    "sweep_transfer",
]

INLINE_THRESHOLD_DEFAULT = 24 * 1024  # bytes — the paper's observed switch


@dataclasses.dataclass
class TransferRecord:
    mode: str                  # inline | direct
    nbytes: int
    build_s: float             # compile/stage cost (once per shape for inline)
    submit_s: float            # per-call dispatch cost
    complete_s: float          # to completion
    bandwidth_gib_s: float


class _InlineCache:
    """Compiled materializer executables keyed by array fingerprint.

    The inline path embeds the payload as a constant in the executable; the
    compile is the 'staging' cost (≙ the driver writing payload bytes into
    the pushbuffer) and each dispatch is the doorbell+engine cost.
    """

    def __init__(self, maxsize: int = 64) -> None:
        self._cache: Dict[Any, Any] = {}
        self._maxsize = maxsize

    def get(self, key: Any) -> Optional[Any]:
        return self._cache.get(key)

    def put(self, key: Any, compiled: Any) -> None:
        if len(self._cache) >= self._maxsize:
            self._cache.pop(next(iter(self._cache)))
        self._cache[key] = compiled


_inline_cache = _InlineCache()


def _fingerprint(x: np.ndarray) -> Tuple:
    """Payload identity: shape/dtype + stable content digest.

    ``blake2b`` (not ``hash()``, which is salted per process) so the key is
    deterministic across processes and safe to persist alongside tuned
    policies.
    """
    digest = hashlib.blake2b(x.tobytes(), digest_size=16).hexdigest()
    return (x.shape, str(x.dtype), digest)


def _emit_transfer(session: Optional[TraceSession], rec: TransferRecord,
                   t: float) -> None:
    sess = resolve_session(session)
    if sess is not None:
        sess.emit("transfer", f"{rec.mode}_put", dur_s=rec.submit_s,
                  complete_s=rec.complete_s, payload_bytes=rec.nbytes, t=t,
                  mode=rec.mode, build_s=rec.build_s,
                  bandwidth_gib_s=rec.bandwidth_gib_s)


def inline_put(x: np.ndarray, device: Optional[Any] = None,
               _cache: bool = True,
               session: Optional[TraceSession] = None,
               ) -> Tuple[jax.Array, TransferRecord]:
    """Move ``x`` to device via the *inline* protocol.

    The payload is baked into an executable as a constant; dispatching the
    executable materializes it on device.  Analogous to inline DMA: the data
    travels inside the command stream and the compute path writes it out.
    """
    x = np.asarray(x)
    # the destination is part of the executable (a materializer pinned to
    # device A cannot serve a put to device B), so it keys the cache too
    key = _fingerprint(x) + (None if device is None else str(device),)
    t0 = time.perf_counter()
    compiled = _inline_cache.get(key) if _cache else None
    build_s = 0.0
    if compiled is None:
        const = jnp.asarray(x)

        def materialize() -> jax.Array:
            # +0 forces a real on-device materialization of the constant
            return const + jnp.zeros((), const.dtype)

        jit_kwargs: Dict[str, Any] = {}
        if device is not None:
            jit_kwargs["out_shardings"] = jax.sharding.SingleDeviceSharding(
                device)
        lowered = jax.jit(materialize, **jit_kwargs).lower()
        compiled = lowered.compile()
        build_s = time.perf_counter() - t0
        if _cache:
            _inline_cache.put(key, compiled)
    t1 = time.perf_counter()
    out = compiled()
    t2 = time.perf_counter()
    jax.block_until_ready(out)
    t3 = time.perf_counter()
    rec = TransferRecord(
        mode="inline", nbytes=x.nbytes, build_s=build_s,
        submit_s=t2 - t1, complete_s=t3 - t1,
        bandwidth_gib_s=x.nbytes / max(t3 - t1, 1e-12) / 2**30)
    _emit_transfer(session, rec, t=t1)
    return out, rec


def direct_put(x: np.ndarray, device: Optional[Any] = None,
               session: Optional[TraceSession] = None,
               ) -> Tuple[jax.Array, TransferRecord]:
    """Move ``x`` to device via the *direct* protocol (explicit transfer)."""
    x = np.asarray(x)
    t1 = time.perf_counter()
    out = jax.device_put(x, device)
    t2 = time.perf_counter()
    jax.block_until_ready(out)
    t3 = time.perf_counter()
    rec = TransferRecord(
        mode="direct", nbytes=x.nbytes, build_s=0.0,
        submit_s=t2 - t1, complete_s=t3 - t1,
        bandwidth_gib_s=x.nbytes / max(t3 - t1, 1e-12) / 2**30)
    _emit_transfer(session, rec, t=t1)
    return out, rec


class HybridMover:
    """Size-switched data movement with an *exposed, tunable* threshold.

    >>> mover = HybridMover(threshold=24 * 1024)
    >>> y, rec = mover.put(np.ones(128, np.float32))
    >>> rec.mode
    'inline'

    ``threshold=None`` (the default) resolves through the active tuned
    policy (:mod:`repro.tune.policy`), falling back to the paper's observed
    switch point — so autotuned deployments pick up their learned threshold
    without every call site knowing about policies.
    """

    def __init__(self, threshold: Optional[int] = None,
                 device: Optional[Any] = None,
                 session: Optional[TraceSession] = None) -> None:
        if threshold is None:
            from ..tune.policy import resolve_knob
            threshold = resolve_knob("dma_threshold_bytes",
                                     INLINE_THRESHOLD_DEFAULT)
        self.threshold = int(threshold)
        self.device = device
        self.records: List[TransferRecord] = []
        self._session = session

    def put(self, x: np.ndarray) -> Tuple[jax.Array, TransferRecord]:
        x = np.asarray(x)
        if x.nbytes < self.threshold:
            out, rec = inline_put(x, self.device, session=self._session)
        else:
            out, rec = direct_put(x, self.device, session=self._session)
        self.records.append(rec)
        return out, rec

    def stats(self) -> Dict[str, int]:
        out = {"inline": 0, "direct": 0}
        for r in self.records:
            out[r.mode] += 1
        return out


def sweep_transfer(sizes_bytes: List[int], mode: str, iters: int = 20,
                   warmup: int = 5, dtype=np.uint8) -> List[Dict[str, float]]:
    """Latency/bandwidth sweep for one protocol — the Figure 6 analogue.

    For the inline path the executable is compiled once per size (staging)
    and then dispatched repeatedly, so the measured time is the dispatch +
    materialization cost — the analogue of the paper's controlled command
    issuance measuring raw engine behaviour without per-call driver work.
    """
    results = []
    put = inline_put if mode == "inline" else direct_put
    for nbytes in sizes_bytes:
        n = max(1, nbytes // np.dtype(dtype).itemsize)
        x = np.arange(n, dtype=np.int64).astype(dtype)
        for _ in range(warmup):
            out, _ = put(x)
            jax.block_until_ready(out)
        lat = []
        for _ in range(iters):
            out, rec = put(x)
            lat.append(rec.complete_s)
        lat.sort()
        med = lat[len(lat) // 2]
        results.append({
            "mode": mode, "nbytes": int(x.nbytes),
            "latency_us": med * 1e6,
            "bandwidth_gib_s": x.nbytes / max(med, 1e-12) / 2**30,
        })
    return results
