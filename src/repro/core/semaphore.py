"""Progress trackers — the memory-semaphore analogue.

The paper (§4.3) describes NVIDIA's *memory semaphore*: the driver appends a
semaphore-release command (target address + payload) after a submitted
sequence; the payload appearing at the address proves everything before it
completed, and an optional timestamp gives device-side timing.  The paper's
controlled DMA benchmark (§6.2) brackets a command sequence between two
trackers and subtracts their timestamps.

On JAX the completion fence is ``block_until_ready`` on an output buffer.
:class:`ProgressTracker` reproduces the semaphore *protocol*: ``release()``
appends a marker to a submission, ``wait()`` fences on it and records the
completion timestamp; ``elapsed()`` between two releases is the analogue of
``cudaEventElapsedTime``.  :class:`Heartbeat` builds the fault-tolerance
liveness signal on top (see ``runtime/fault_tolerance.py``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from .session import TraceSession, resolve_session

__all__ = ["SemaphoreToken", "ProgressTracker", "Heartbeat"]


@dataclasses.dataclass
class SemaphoreToken:
    """One semaphore release: (payload, fence buffer, timestamps)."""

    payload: int
    fence: Any                 # the device buffer acting as the semaphore
    t_release: float           # host time when the release was submitted
    t_complete: Optional[float] = None

    @property
    def completed(self) -> bool:
        return self.t_complete is not None


class ProgressTracker:
    """Semaphore-release/wait protocol over JAX buffers."""

    def __init__(self, session: Optional[TraceSession] = None) -> None:
        self._next_payload = 1
        self.tokens: List[SemaphoreToken] = []
        self._session = session

    def release(self, tied_to: Any) -> SemaphoreToken:
        """Append a release after ``tied_to`` (any pytree of device arrays).

        The fence value is data-dependent on ``tied_to`` so its readiness
        implies completion of everything that produced ``tied_to`` — the same
        in-order guarantee the hardware semaphore gives within a channel.
        """
        payload = self._next_payload
        self._next_payload += 1
        leaves = [l for l in jax.tree_util.tree_leaves(tied_to)
                  if hasattr(l, "dtype")]
        if leaves:
            x = leaves[0]
            zero = (x.ravel()[0] * 0).astype(jnp.int32) if x.size else jnp.int32(0)
            fence = zero + jnp.int32(payload)
        else:
            fence = jnp.int32(payload)
        tok = SemaphoreToken(payload=payload, fence=fence,
                             t_release=time.perf_counter())
        self.tokens.append(tok)
        sess = resolve_session(self._session)
        if sess is not None:
            sess.emit("progress", "release", t=tok.t_release, payload=payload)
        return tok

    def wait(self, token: SemaphoreToken) -> float:
        """Block until the semaphore value lands; record its timestamp."""
        val = int(jax.block_until_ready(token.fence))
        if val != token.payload:
            raise RuntimeError(
                f"semaphore payload mismatch: expected {token.payload}, "
                f"observed {val}")
        token.t_complete = time.perf_counter()
        sess = resolve_session(self._session)
        if sess is not None:
            sess.emit("progress", "wait", t=token.t_complete,
                      complete_s=token.t_complete - token.t_release,
                      payload=token.payload)
        return token.t_complete

    def elapsed(self, a: SemaphoreToken, b: SemaphoreToken) -> float:
        """Elapsed completion-to-completion time between two releases."""
        if not a.completed:
            self.wait(a)
        if not b.completed:
            self.wait(b)
        return abs(b.t_complete - a.t_complete)


class Heartbeat:
    """Liveness/straggler signal built on progress completions.

    Each worker (host, or simulated worker) beats when its step's progress
    tracker completes; ``stragglers()`` flags workers whose most recent beat
    lags the median by more than ``factor``× the median inter-beat interval.
    """

    def __init__(self, n_workers: int, factor: float = 3.0) -> None:
        self.n_workers = int(n_workers)
        self.factor = float(factor)
        self.last_beat: Dict[int, float] = {}
        self.intervals: Dict[int, List[float]] = {i: [] for i in range(n_workers)}

    def beat(self, worker: int, t: Optional[float] = None) -> None:
        t = time.perf_counter() if t is None else t
        prev = self.last_beat.get(worker)
        if prev is not None:
            self.intervals[worker].append(t - prev)
        self.last_beat[worker] = t

    def _median_interval(self) -> float:
        allint = sorted(x for xs in self.intervals.values() for x in xs)
        if not allint:
            return 0.0
        return allint[len(allint) // 2]

    def stragglers(self, now: Optional[float] = None) -> List[int]:
        now = time.perf_counter() if now is None else now
        med = self._median_interval()
        if med <= 0:
            return []
        out = []
        for w in range(self.n_workers):
            last = self.last_beat.get(w)
            if last is None or (now - last) > self.factor * med:
                out.append(w)
        return out

    def dead(self, timeout_s: float, now: Optional[float] = None) -> List[int]:
        now = time.perf_counter() if now is None else now
        return [w for w in range(self.n_workers)
                if self.last_beat.get(w) is None
                or (now - self.last_beat[w]) > timeout_s]
