"""Core: the paper's contribution as a first-class JAX subsystem.

Command-stream visibility for the JAX/XLA/TPU stack, adapted from
"Revealing NVIDIA Closed-Source Driver Command Streams for CPU-GPU Runtime
Behavior Insight":

* :mod:`repro.core.session`   — unified TraceSession: one event timeline
* :mod:`repro.core.capture`   — capture at the submission boundary
* :mod:`repro.core.hlo`       — command-stream reconstruction/decoding
* :mod:`repro.core.doorbell`  — submission-cycle (dispatch) tracking
* :mod:`repro.core.dma`       — inline vs direct data-movement protocols
* :mod:`repro.core.graphs`    — launch modes & the command-footprint law
* :mod:`repro.core.semaphore` — progress trackers (memory-semaphore analogue)
* :mod:`repro.core.roofline`  — 3-term roofline from captured streams
* :mod:`repro.core.report`    — Listing-1-style decoded reports
"""
from .session import (BARRIER_EVENT, EVENT_KINDS, SPAN_EVENT, JsonlSink,
                      RingBufferSink, Sink, SpanFrame, SpanHandle, TraceEvent,
                      TraceSession, ambient_span, current_session)
from .capture import CapturedStream, CommandStreamCapture, capture_fn
from .dma import (HybridMover, INLINE_THRESHOLD_DEFAULT, TransferRecord,
                  direct_put, inline_put, sweep_transfer)
from .doorbell import DoorbellRecord, DoorbellTracker, payload_bytes
from .graphs import LAUNCH_MODES, ExecGraph, LaunchStats, MultiStepLauncher
from .hlo import COLLECTIVE_OPS, CommandEntry, CommandStream, parse_hlo
from .report import render_submission, render_roofline_row
from .roofline import (HW, TPU_V5E, RooflineReport, adjusted, analyze,
                       attribute, model_flops)
from .semaphore import Heartbeat, ProgressTracker, SemaphoreToken

__all__ = [
    "BARRIER_EVENT", "EVENT_KINDS", "SPAN_EVENT", "JsonlSink",
    "RingBufferSink", "Sink", "SpanFrame", "SpanHandle", "TraceEvent",
    "TraceSession", "ambient_span", "current_session",
    "CapturedStream", "CommandStreamCapture", "capture_fn",
    "HybridMover", "INLINE_THRESHOLD_DEFAULT", "TransferRecord",
    "direct_put", "inline_put", "sweep_transfer",
    "DoorbellRecord", "DoorbellTracker", "payload_bytes",
    "LAUNCH_MODES", "ExecGraph", "LaunchStats", "MultiStepLauncher",
    "COLLECTIVE_OPS", "CommandEntry", "CommandStream", "parse_hlo",
    "render_submission", "render_roofline_row",
    "HW", "TPU_V5E", "RooflineReport", "adjusted", "analyze",
    "attribute", "model_flops",
    "Heartbeat", "ProgressTracker", "SemaphoreToken",
]
