"""Doorbell (dispatch) tracking.

In the paper, the doorbell write is the driver's final commit point for a
submission cycle; counting doorbell writes counts submission cycles, and the
watchpoint guarantees every one is observed.  On the JAX/PJRT stack the commit
point of a submission is the dispatch of a compiled executable.

:class:`DoorbellTracker` owns that dispatch boundary: callables wrapped by a
tracker ring its "doorbell" on every call, recording the submission timestamp,
the wall time to enqueue (dispatch, async) and optionally to complete, and the
argument payload bytes.  This is the measurement substrate for the CUDA-Graph
case study (dispatch counts ≙ doorbell writes) and for the Trainer's
submission accounting.

Every recorded cycle is also published as a ``dispatch`` event on the bound
or ambient :class:`~repro.core.session.TraceSession` (see that module);
standalone use without a session is unchanged.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional

import jax

from .session import TraceSession, resolve_session

__all__ = ["DoorbellRecord", "DoorbellTracker", "payload_bytes"]


def payload_bytes(tree: Any) -> int:
    """Bytes of array arguments in a pytree (the 'submission payload')."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        nb = getattr(leaf, "nbytes", None)
        if nb is None:
            size = getattr(leaf, "size", 1)
            itemsize = getattr(getattr(leaf, "dtype", None), "itemsize", 4)
            nb = size * itemsize
        total += int(nb)
    return total


@dataclasses.dataclass
class DoorbellRecord:
    """One submission cycle."""

    seq: int
    name: str
    t_submit: float            # perf_counter at dispatch
    dispatch_s: float          # time to enqueue (returns before completion)
    complete_s: float          # time to completion (if blocked)
    payload_bytes: int


class DoorbellTracker:
    """Counts and times submission cycles ("doorbell writes")."""

    def __init__(self, session: Optional[TraceSession] = None) -> None:
        self.records: List[DoorbellRecord] = []
        self._seq = 0
        self._session = session

    # -- wrapping ----------------------------------------------------------
    def wrap(self, fn: Callable, name: str = "dispatch",
             block: bool = False) -> Callable:
        """Wrap a (compiled/jitted) callable so each call rings the doorbell.

        With ``block=True`` the wrapper waits for completion and records the
        full duration; otherwise only the (async) dispatch time is recorded —
        the analogue of the doorbell write returning immediately while the
        GPU consumes the GPFIFO.
        """
        @functools.wraps(fn)
        def wrapped(*args, **kwargs):
            t0 = time.perf_counter()
            out = fn(*args, **kwargs)
            t1 = time.perf_counter()
            complete = 0.0
            if block:
                jax.block_until_ready(out)
                complete = time.perf_counter() - t0
            self._record(name, t0, t1 - t0, complete,
                         payload_bytes((args, kwargs)))
            return out
        return wrapped

    def ring(self, name: str = "manual", payload: int = 0) -> None:
        """Explicitly record a submission cycle."""
        t = time.perf_counter()
        self._record(name, t, 0.0, 0.0, payload)

    def _record(self, name: str, t0: float, disp: float, comp: float,
                payload: int) -> None:
        self.records.append(DoorbellRecord(
            seq=self._seq, name=name, t_submit=t0, dispatch_s=disp,
            complete_s=comp, payload_bytes=payload))
        self._seq += 1
        sess = resolve_session(self._session)
        if sess is not None:
            sess.emit("dispatch", name, dur_s=disp, complete_s=comp,
                      payload_bytes=payload, t=t0)

    # -- accounting --------------------------------------------------------
    @property
    def count(self) -> int:
        return len(self.records)

    def count_for(self, name: str) -> int:
        return sum(1 for r in self.records if r.name == name)

    def total_dispatch_s(self, name: Optional[str] = None) -> float:
        return sum(r.dispatch_s for r in self.records
                   if name is None or r.name == name)

    def total_payload(self, name: Optional[str] = None) -> int:
        return sum(r.payload_bytes for r in self.records
                   if name is None or r.name == name)

    def reset(self) -> None:
        self.records.clear()
        self._seq = 0

    def summary(self) -> Dict[str, Any]:
        by_name: Dict[str, Dict[str, float]] = {}
        for r in self.records:
            d = by_name.setdefault(r.name, {"doorbells": 0, "dispatch_s": 0.0,
                                            "payload_bytes": 0})
            d["doorbells"] += 1
            d["dispatch_s"] += r.dispatch_s
            d["payload_bytes"] += r.payload_bytes
        return {"total_doorbells": self.count, "by_name": by_name}
