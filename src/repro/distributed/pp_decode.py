"""Pipeline-parallel decode for dense LMs (shard_map: PP × TP × SP-KV).

The FSDP decode baseline must all-gather every weight shard once per token
(47 GB/device/token for llama3-405b — the dominant collective term of the
decode_32k cell).  Pipelining layers over the 'data' axis makes the weights
STATIONARY: each of the 16 stages holds L/16 layers TP-sharded over 'model',
activations [µb,1,D] hop stage→stage via collective-permute (256 KB vs 47 GB).

The schedule is the *steady-state circular* pipeline: one launch = n_stages
ticks; tick t has stage s serving microbatch (t−s) mod n_µb, so every stage
is busy every tick — zero bubble.  Microbatches with t < s are still
carrying the PREVIOUS launch's token (pipeline lag = n_stages−1 ticks): the
activation wire and the per-µb token-position offset are part of the decode
state, and logits emerge with that lag, exactly like a production decode
pipeline (per-sequence latency = pipeline depth, throughput = bubble-free).

Inside a stage everything is manual TP over 'model':
  * Q heads sharded; KV heads replicated (kv < tp), each device's Q-head
    block maps to a single KV group (requires (H/hk) % (H/tp) == 0);
  * KV cache sequence-sharded over 'model'; the new token's K/V is written
    only by the shard owning the in-flight position (masked in-place update);
  * attention is flash-decoding: local partial softmax over the owned
    sequence slice, combined with pmax/psum over 'model';
  * o-proj / MLP down-proj produce partials → psum over 'model';
  * embed/unembed are vocab-sharded: masked local lookup + psum.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..configs.base import ModelConfig
from ..models.layers import apply_rope, rms_norm, rotary
from .context import shard_map

__all__ = ["PPDecoder"]

NEG = float(jnp.finfo(jnp.float32).min)


@dataclasses.dataclass
class PPDecoder:
    """Builds the shard_map'd steady-state decode step for a dense LM."""

    cfg: ModelConfig
    mesh: Mesh
    stage_axis: str = "data"
    tp_axis: str = "model"
    tokens_per_launch: int = 1   # T: tokens scored per launch (amortizes the
                                 # per-tick weight stream T× — §Perf)

    def __post_init__(self) -> None:
        cfg = self.cfg
        assert cfg.family in ("dense", "vlm"), "PP decode targets dense LMs"
        self.n_stages = int(self.mesh.shape[self.stage_axis])
        self.tp = int(self.mesh.shape[self.tp_axis])
        self.layers_per_stage = -(-cfg.n_layers // self.n_stages)
        self.n_virtual = self.layers_per_stage * self.n_stages
        h_loc = cfg.n_heads_padded // self.tp
        n_rep = cfg.n_heads_padded // cfg.n_kv_heads
        assert n_rep % h_loc == 0 or h_loc % n_rep == 0, \
            "local Q-head block must map to one KV group"

    # ------------------------------------------------------------------
    def init_params(self, key: jax.Array) -> Dict[str, Any]:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        from ..models.layers import init_embedding, init_rms_norm
        from ..models.transformer import init_block
        keys = jax.random.split(key, self.n_virtual)
        layers = jax.vmap(lambda k: init_block(k, cfg, dtype))(keys)
        layers = jax.tree_util.tree_map(
            lambda a: a.reshape((self.n_stages, self.layers_per_stage)
                                + a.shape[1:]), layers)
        k_emb, _ = jax.random.split(key)
        return {
            "emb": init_embedding(k_emb, cfg.vocab_padded, cfg.d_model,
                                  dtype, cfg.tie_embeddings),
            "layers": layers,
            "final_norm": init_rms_norm(cfg.d_model, dtype),
            "valid": (jnp.arange(self.n_virtual) < cfg.n_layers).reshape(
                self.n_stages, self.layers_per_stage),
        }

    def init_state(self, batch: int, max_seq: int) -> Dict[str, Any]:
        cfg = self.cfg
        dtype = jnp.dtype(cfg.param_dtype)
        shape = (self.n_stages, self.layers_per_stage, batch, max_seq,
                 cfg.n_kv_heads, cfg.hd)
        wire = (self.n_stages, batch // self.n_stages,
                self.tokens_per_launch, cfg.d_model)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
                "wire": jnp.zeros(wire, dtype),
                "length": jnp.zeros((), jnp.int32)}

    # ------------------------------------------------------------------
    def param_specs(self):
        sa, ta = self.stage_axis, self.tp_axis

        def spec(path, leaf):
            keys = [str(getattr(k, "key", getattr(k, "idx", k)))
                    for k in path]
            name = keys[-1] if keys else ""
            nd = len(leaf.shape)
            if keys and keys[0] == "layers":
                if name == "wq":
                    return P(sa, None, None, ta, None)
                if name in ("wk", "wv"):
                    return P(sa, None, None, None, None)
                if name == "wo":
                    return P(sa, None, ta, None, None)
                if name in ("w_gate", "w_up"):
                    return P(sa, None, None, ta)
                if name == "w_down":
                    return P(sa, None, ta, None)
                return P(*([sa] + [None] * (nd - 1)))
            if keys and keys[0] == "emb":
                return P(ta, None) if name == "embed" else P(None, ta)
            if keys and keys[0] == "valid":
                return P(sa, None)
            return P()

        return jax.tree_util.tree_map_with_path(
            spec, jax.eval_shape(
                lambda: self.init_params(jax.random.PRNGKey(0))))

    def state_specs(self):
        sa, ta = self.stage_axis, self.tp_axis
        return {"k": P(sa, None, None, ta, None, None),
                "v": P(sa, None, None, ta, None, None),
                "wire": P(sa, None, None, None),
                "length": P()}

    # ------------------------------------------------------------------
    def make_step(self, batch: int, max_seq: int):
        cfg = self.cfg
        sa, ta = self.stage_axis, self.tp_axis
        n_stages, tp = self.n_stages, self.tp
        n_micro = n_stages
        lps = self.layers_per_stage
        T = self.tokens_per_launch
        assert batch % n_micro == 0
        mb = batch // n_micro
        seq_loc = max_seq // tp
        h_loc = cfg.n_heads_padded // tp
        hk, hd, D = cfg.n_kv_heads, cfg.hd, cfg.d_model
        n_rep = cfg.n_heads_padded // hk
        v_loc = cfg.vocab_padded // tp
        act = jax.nn.silu if cfg.act == "silu" else jax.nn.gelu

        def embed_local(emb, ids, t_idx):
            off = t_idx * v_loc
            local = jnp.clip(ids - off, 0, v_loc - 1)
            rows = jnp.take(emb, local, axis=0)
            ok = (ids >= off) & (ids < off + v_loc)
            return jax.lax.psum(jnp.where(ok[..., None], rows, 0), ta)

        def layer_decode(lp, valid, x, k_c, v_c, pos_tok, t_idx):
            """x: [mb,T,D] (a T-token segment, causal via write-then-score);
            k_c/v_c: [mb, seq_loc, hk, hd] (local sequence slice)."""
            ap = lp["attn"]
            h = rms_norm(lp["ln1"], x)
            q = jnp.einsum("bsd,dhk->bshk", h, ap["wq"])       # h_loc heads
            k_new = jnp.einsum("bsd,dhk->bshk", h, ap["wk"])   # hk heads
            v_new = jnp.einsum("bsd,dhk->bshk", h, ap["wv"])
            if cfg.qk_norm:
                q = rms_norm(ap["q_norm"], q)
                k_new = rms_norm(ap["k_norm"], k_new)
            positions = pos_tok + jnp.arange(T)
            if cfg.pos_embed == "rope":
                sin, cos = rotary(positions[None], hd, cfg.rope_theta)
                q = apply_rope(q, sin, cos)
                k_new = apply_rope(k_new, sin, cos)
            # ---- masked seq-sharded cache writes (one row per token) -----
            # write-then-score keeps intra-segment causality: token j's row
            # is in the cache before any token scores it, and token j's own
            # position mask hides rows > pos_tok+j.
            for j in range(T):
                pj = pos_tok + j
                owner = ((pj // seq_loc) == t_idx) & valid
                p_loc = pj % seq_loc
                k_row = jax.lax.dynamic_slice(k_c, (0, p_loc, 0, 0),
                                              (mb, 1, hk, hd))
                v_row = jax.lax.dynamic_slice(v_c, (0, p_loc, 0, 0),
                                              (mb, 1, hk, hd))
                k_c = jax.lax.dynamic_update_slice(
                    k_c, jnp.where(owner, k_new[:, j:j + 1].astype(k_c.dtype),
                                   k_row), (0, p_loc, 0, 0))
                v_c = jax.lax.dynamic_update_slice(
                    v_c, jnp.where(owner, v_new[:, j:j + 1].astype(v_c.dtype),
                                   v_row), (0, p_loc, 0, 0))
            # ---- flash-decoding over the local sequence slice ------------
            # the tp axis partitions the SEQUENCE inside attention: gather
            # the (tiny) q so every device scores ALL heads over its slice,
            # then combine per head across slices with pmax/psum and slice
            # back to the local head block for the o-proj partial.
            # KV is read in bf16 with fp32 MXU accumulation — converting the
            # cache to fp32 would double its HBM traffic.
            q_all = jax.lax.all_gather(q, ta, axis=2, tiled=True)
            qf = q_all.reshape(mb, T, hk, n_rep, hd).astype(k_c.dtype)
            s = jnp.einsum("btgrd,bsgd->btgrs", qf, k_c,
                           preferred_element_type=jnp.float32) * (hd ** -0.5)
            gpos = t_idx * seq_loc + jnp.arange(seq_loc)
            tmask = gpos[None, :] <= positions[:, None]        # [T, seq_loc]
            s = jnp.where(tmask[None, :, None, None, :], s, NEG)
            m_loc = jnp.max(s, axis=-1)
            m_glob = jax.lax.pmax(m_loc, ta)
            p_ = jnp.exp(s - m_glob[..., None])
            l_glob = jax.lax.psum(jnp.sum(p_, axis=-1), ta)
            acc = jax.lax.psum(
                jnp.einsum("btgrs,bsgd->btgrd", p_.astype(k_c.dtype), v_c,
                           preferred_element_type=jnp.float32), ta)
            out = (acc / jnp.maximum(l_glob, 1e-30)[..., None])
            out = out.reshape(mb, T, cfg.n_heads_padded, hd)
            out = jax.lax.dynamic_slice(
                out, (0, 0, t_idx * h_loc, 0), (mb, T, h_loc, hd))
            out = out.astype(x.dtype)                          # [mb,T,h_loc,hd]
            attn = jax.lax.psum(
                jnp.einsum("bshk,hkd->bsd", out, ap["wo"]), ta)
            x = x + jnp.where(valid, attn, 0).astype(x.dtype)
            # ---- MLP ----------------------------------------------------
            h2 = rms_norm(lp["ln2"], x)
            mp = lp["mlp"]
            m = (act(h2 @ mp["w_gate"]) * (h2 @ mp["w_up"])) @ mp["w_down"]
            m = jax.lax.psum(m, ta)
            x = x + jnp.where(valid, m, 0).astype(x.dtype)
            return x, k_c, v_c

        def stage_fn(params, kv_k, kv_v, wire, length, tokens):
            s_idx = jax.lax.axis_index(sa)
            t_idx = jax.lax.axis_index(ta)
            # drop the local stage dim (block size 1 along the stage axis)
            layers = jax.tree_util.tree_map(lambda a: a[0], params["layers"])
            valid_l = params["valid"][0]
            kv_k = kv_k[0]
            kv_v = kv_v[0]
            emb = params["emb"]["embed"]
            unemb = params["emb"].get("unembed")
            logits_acc = jnp.zeros((n_micro, mb, T, v_loc), jnp.float32)
            x_wire = wire[0]                                   # [mb,1,D] local

            def tick(carry, t):
                x_wire, kv_k, kv_v, logits_acc = carry
                mb_idx = (t - s_idx) % n_micro
                # µbatches that wrapped (t < s) still carry the previous
                # launch's T-token segment
                pos_tok = length - T * (t < s_idx).astype(jnp.int32)
                toks = jax.lax.dynamic_slice(
                    tokens, (mb_idx * mb, 0), (mb, T))
                x0 = embed_local(emb, toks, t_idx).astype(x_wire.dtype)
                if cfg.embed_scale:
                    x0 = x0 * jnp.asarray(D ** 0.5, x0.dtype)
                x = jnp.where(s_idx == 0, x0, x_wire)

                def one_layer(l, carry):
                    # fori_loop with in-place DUS: scanning kv through ys
                    # would rewrite the FULL stage cache every tick
                    x, kv_k, kv_v = carry
                    lp = jax.tree_util.tree_map(
                        lambda a: jax.lax.dynamic_index_in_dim(
                            a, l, 0, keepdims=False), layers)
                    valid = valid_l[l]
                    kb = jax.lax.dynamic_slice(
                        kv_k, (l, mb_idx * mb, 0, 0, 0),
                        (1, mb, seq_loc, hk, hd))[0]
                    vb = jax.lax.dynamic_slice(
                        kv_v, (l, mb_idx * mb, 0, 0, 0),
                        (1, mb, seq_loc, hk, hd))[0]
                    x, kb, vb = layer_decode(lp, valid, x, kb, vb,
                                             pos_tok, t_idx)
                    kv_k = jax.lax.dynamic_update_slice(
                        kv_k, kb[None], (l, mb_idx * mb, 0, 0, 0))
                    kv_v = jax.lax.dynamic_update_slice(
                        kv_v, vb[None], (l, mb_idx * mb, 0, 0, 0))
                    return x, kv_k, kv_v

                x, kv_k, kv_v = jax.lax.fori_loop(
                    0, lps, one_layer, (x, kv_k, kv_v))
                # ---- last stage: unembed, bank logits for this µb --------
                xn = rms_norm(params["final_norm"], x)
                lg = (xn @ unemb if unemb is not None
                      else xn @ emb.T).astype(jnp.float32)
                is_last = (s_idx == n_stages - 1).astype(jnp.float32)
                logits_acc = jax.lax.dynamic_update_slice(
                    logits_acc, (lg * is_last)[None],
                    (mb_idx, 0, 0, 0))
                # ---- hop to the next stage -------------------------------
                perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
                x_wire = jax.lax.ppermute(x, sa, perm)
                return (x_wire, kv_k, kv_v, logits_acc), ()

            (x_wire, kv_k, kv_v, logits_acc), _ = jax.lax.scan(
                tick, (x_wire, kv_k, kv_v, logits_acc), jnp.arange(n_micro))
            logits = jax.lax.psum(logits_acc, sa)   # only last stage nonzero
            logits = logits.reshape(batch, T, v_loc)
            return kv_k[None], kv_v[None], x_wire[None], logits

        p_specs = self.param_specs()
        kv_spec = P(sa, None, None, ta, None, None)
        wire_spec = P(sa, None, None, None)

        def step(params, state, tokens):
            kv_k, kv_v, wire, logits = shard_map(
                stage_fn, mesh=self.mesh,
                in_specs=(p_specs, kv_spec, kv_spec, wire_spec, P(),
                          P(None, None)),
                out_specs=(kv_spec, kv_spec, wire_spec, P(None, None, ta)),
                check_vma=False,
            )(params, state["k"], state["v"], state["wire"],
              state["length"], tokens)
            return {"k": kv_k, "v": kv_v, "wire": wire,
                    "length": state["length"] + T}, logits

        return step
