from .sharding import ShardingRules, dp_axes, mesh_axis_size

__all__ = ["ShardingRules", "dp_axes", "mesh_axis_size"]
