from .context import process_info, process_tags, shard_path
from .sharding import ShardingRules, dp_axes, mesh_axis_size

__all__ = ["ShardingRules", "dp_axes", "mesh_axis_size",
           "process_info", "process_tags", "shard_path"]
