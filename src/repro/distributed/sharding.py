"""Sharding rule engine: TP / FSDP / ZeRO-1 / sequence-parallel KV.

Rules are keyed on parameter-tree path suffixes and resolved against the
actual leaf shapes: an axis is only assigned when the dimension divides the
mesh axis size, otherwise it is dropped (replicated) and recorded — every
(arch × shape × mesh) cell must lower, never error on divisibility.

Axis convention (see launch/mesh.py):
  pod    — data-parallel across pods (multi-pod only)
  data   — data-parallel within a pod; also FSDP/ZeRO-1 weight sharding
  model  — tensor parallel (heads / d_ff / vocab / ssm-heads)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig

__all__ = ["ShardingRules", "dp_axes", "mesh_axis_size"]

Axis = Union[str, Tuple[str, ...], None]


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """The data-parallel axes: ('pod', 'data') on multi-pod meshes."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def mesh_axis_size(mesh: Mesh, axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh.shape[axis] if axis in mesh.axis_names else 1
    n = 1
    for a in axis:
        n *= mesh_axis_size(mesh, a)
    return n


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


# Parameter rules: (suffix regex-free match, dims spec template).
# Template entries: axis name, None, or "fsdp" (replaced by the dp axes when
# cfg.fsdp, else dropped).  Leading scan/stack dims are auto-padded with None.
_PARAM_RULES: List[Tuple[str, Tuple[Any, ...]]] = [
    # embeddings
    ("emb/embed", ("model", "fsdp")),
    ("emb/unembed", ("fsdp", "model")),
    ("pos_dec", (None, None)),
    # attention
    ("attn/wq", ("fsdp", "model", None)),
    ("attn/wk", ("fsdp", "kv_model", None)),
    ("attn/wv", ("fsdp", "kv_model", None)),
    ("attn/wo", ("model", None, "fsdp")),
    ("xattn/wq", ("fsdp", "model", None)),
    ("xattn/wk", ("fsdp", "kv_model", None)),
    ("xattn/wv", ("fsdp", "kv_model", None)),
    ("xattn/wo", ("model", None, "fsdp")),
    # dense mlp
    ("mlp/w_gate", ("fsdp", "model")),
    ("mlp/w_up", ("fsdp", "model")),
    ("mlp/w_down", ("model", "fsdp")),
    # moe (expert-internal TP baseline; see docs for EP variant)
    ("moe/router", (None, None)),
    ("moe/w_gate", ("expert", "fsdp", "model")),
    ("moe/w_up", ("expert", "fsdp", "model")),
    ("moe/w_down", ("expert", "model", "fsdp")),
    ("shared/w_gate", (None, "fsdp", "model")),
    ("shared/w_up", (None, "fsdp", "model")),
    ("shared/w_down", (None, "model", "fsdp")),
    # mamba (x-path TP over d_inner / heads; B/C paths replicated)
    ("mamba/z_proj", ("fsdp", "model")),
    ("mamba/x_proj", ("fsdp", "model")),
    ("mamba/B_proj", ("fsdp", None)),
    ("mamba/C_proj", ("fsdp", None)),
    ("mamba/dt_proj", ("fsdp", "model")),
    ("mamba/conv_x_w", (None, "model")),
    ("mamba/conv_x_b", ("model",)),
    ("mamba/conv_B_w", (None, None)),
    ("mamba/conv_B_b", (None,)),
    ("mamba/conv_C_w", (None, None)),
    ("mamba/conv_C_b", (None,)),
    ("mamba/A_log", ("model",)),
    ("mamba/D", ("model",)),
    ("mamba/dt_bias", ("model",)),
    ("mamba/norm/scale", ("model",)),
    ("mamba/out_proj", ("model", "fsdp")),
    # norms & everything else: replicated
]


@dataclasses.dataclass
class ShardingRules:
    mesh: Mesh
    cfg: ModelConfig
    zero1: bool = True            # shard optimizer state over dp axes
    dropped: List[str] = dataclasses.field(default_factory=list)

    # ---- helpers ------------------------------------------------------------
    def _dp(self) -> Tuple[str, ...]:
        return dp_axes(self.mesh)

    def _resolve_axis(self, token: Any, dim: int) -> Axis:
        """Map a rule token to a concrete mesh axis (or None)."""
        if token is None:
            return None
        if token == "fsdp":
            if not self.cfg.fsdp:
                return None
            axes = self._dp()
            return axes if axes else None
        if token == "kv_model":
            return "model"
        if token == "expert":
            return None  # baseline: experts replicated (TP inside experts)
        return token

    def _fit(self, axis: Axis, size: int, where: str) -> Axis:
        n = mesh_axis_size(self.mesh, axis)
        if n <= 1:
            return None
        if size % n == 0:
            return axis
        self.dropped.append(f"{where}: dim {size} % axis {axis}({n}) != 0")
        # try a partial fit for tuple axes (e.g. ('pod','data') -> 'data')
        if isinstance(axis, tuple) and len(axis) > 1:
            return self._fit(axis[-1], size, where)
        return None

    # ---- parameters -----------------------------------------------------------
    def param_spec(self, path: str, shape: Sequence[int]) -> P:
        for suffix, dims in _PARAM_RULES:
            if path.endswith(suffix):
                nd = len(shape)
                tmpl = list(dims)
                # leading stacked dims (scan over layers/groups/experts-of-
                # shared) are unsharded
                pad = nd - len(tmpl)
                if pad < 0:
                    tmpl = tmpl[-nd:] if nd else []
                    pad = 0
                axes: List[Axis] = [None] * pad + [
                    self._resolve_axis(t, 0) for t in tmpl]
                used: set = set()
                out: List[Axis] = []
                for d, ax in zip(shape, axes):
                    ax = self._fit(ax, d, path)
                    # one mesh axis may appear at most once per spec
                    key = tuple(ax) if isinstance(ax, tuple) else ax
                    if ax is not None and key in used:
                        ax = None
                    if ax is not None:
                        used.add(key)
                    out.append(ax)
                return P(*out)
        return P()  # replicate (norm scales, biases, scalars)

    def param_specs(self, params: Any) -> Any:
        return jax.tree_util.tree_map_with_path(
            lambda p, leaf: self.param_spec(_path_str(p), leaf.shape), params)

    def param_shardings(self, params: Any) -> Any:
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s), self.param_specs(params))

    # ---- optimizer state (ZeRO-1) ----------------------------------------------
    def opt_spec(self, path: str, shape: Sequence[int]) -> P:
        """Optimizer-state leaf: param spec + dp sharding on the first
        free divisible dim (ZeRO-1).  With fsdp the param spec already
        shards over dp; nothing more to do."""
        base = self.param_spec(path, shape)
        if not self.zero1 or self.cfg.fsdp:
            return base
        dp = self._dp()
        if not dp:
            return base
        dpn = mesh_axis_size(self.mesh, dp)
        spec = list(base) + [None] * (len(shape) - len(base))
        flat_used = set()
        for ax in spec:
            for a in (ax if isinstance(ax, tuple) else (ax,)):
                if a:
                    flat_used.add(a)
        if any(a in flat_used for a in dp):
            return base
        for i, (d, ax) in enumerate(zip(shape, spec)):
            if ax is None and d % dpn == 0 and d >= dpn:
                spec[i] = dp if len(dp) > 1 else dp[0]
                return P(*spec)
        return base

    def opt_specs(self, params: Any) -> Any:
        return jax.tree_util.tree_map_with_path(
            lambda p, leaf: self.opt_spec(_path_str(p), leaf.shape), params)

    # ---- activations / batches ----------------------------------------------------
    def batch_spec(self, batch_size: int) -> Axis:
        dp = self._dp()
        if not dp:
            return None
        return self._fit(dp if len(dp) > 1 else dp[0], batch_size, "batch")

    def data_specs(self, batch: Any) -> Any:
        """Input batch: shard dim0 (global batch) over dp axes."""
        def spec(leaf):
            if not hasattr(leaf, "shape") or len(leaf.shape) == 0:
                return P()
            ax = self.batch_spec(leaf.shape[0])
            return P(*([ax] + [None] * (len(leaf.shape) - 1)))
        return jax.tree_util.tree_map(spec, batch)

    # ---- decode state -----------------------------------------------------------------
    def state_spec(self, path: str, shape: Sequence[int]) -> P:
        """KV caches [.., B, S, Hkv, hd] / SSM states [.., B, H, P, N].

        Batch shards over dp when divisible.  KV heads shard over model when
        divisible; otherwise, for large caches, the *sequence* dim shards
        over model (flash-decoding layout) or data (batch=1 long-context).
        """
        cfg = self.cfg
        name = path.split("/")[-1]
        nd = len(shape)
        spec: List[Axis] = [None] * nd
        if name in ("k", "v", "xk", "xv"):
            # [..., B, S, Hkv, hd]
            b_i, s_i, h_i = nd - 4, nd - 3, nd - 2
            dp = self._dp()
            batch_ax = self._fit(dp if len(dp) > 1 else (dp[0] if dp else None),
                                 shape[b_i], path)
            spec[b_i] = batch_ax
            if shape[h_i] % mesh_axis_size(self.mesh, "model") == 0:
                spec[h_i] = "model"
            else:
                spec[s_i] = "model" if shape[s_i] % mesh_axis_size(
                    self.mesh, "model") == 0 else None
            if batch_ax is None and dp:
                # batch=1 long-context: shard sequence over data too
                data_fit = self._fit("data", shape[s_i], path)
                if spec[s_i] == "model" and data_fit:
                    spec[s_i] = ("data", "model")
                elif data_fit and spec[s_i] is None:
                    spec[s_i] = "data"
            return P(*spec)
        if name == "h":
            # [..., B, H, P, N]
            b_i, h_i = nd - 4, nd - 3
            dp = self._dp()
            spec[b_i] = self._fit(dp if len(dp) > 1 else (dp[0] if dp else None),
                                  shape[b_i], path)
            spec[h_i] = self._fit("model", shape[h_i], path)
            return P(*spec)
        if name in ("conv_x",):
            b_i, c_i = nd - 3, nd - 1
            dp = self._dp()
            spec[b_i] = self._fit(dp if len(dp) > 1 else (dp[0] if dp else None),
                                  shape[b_i], path)
            spec[c_i] = self._fit("model", shape[c_i], path)
            return P(*spec)
        if name in ("conv_B", "conv_C"):
            b_i = nd - 3
            dp = self._dp()
            spec[b_i] = self._fit(dp if len(dp) > 1 else (dp[0] if dp else None),
                                  shape[b_i], path)
            return P(*spec)
        return P()  # length scalar etc.

    def state_specs(self, state: Any) -> Any:
        return jax.tree_util.tree_map_with_path(
            lambda p, leaf: self.state_spec(_path_str(p), leaf.shape), state)

    # ---- shardings helpers -------------------------------------------------------------
    def to_shardings(self, specs: Any) -> Any:
        return jax.tree_util.tree_map(
            lambda s: NamedSharding(self.mesh, s), specs,
            is_leaf=lambda x: isinstance(x, P))
