"""Ambient mesh context for model-internal shard_map blocks.

Model code (e.g. the shard_map MoE) needs the active mesh + data-parallel
axis names; launchers set them here.  Kept explicit (not jax's global mesh)
so models stay traceable without a mesh for single-device tests.

Also hosts :func:`shard_map` — a version-compat wrapper over
``jax.shard_map`` (jax ≥ 0.5, ``check_vma=``) and
``jax.experimental.shard_map.shard_map`` (older jax, ``check_rep=``).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax

_MESH = None
_DP_AXES: Tuple[str, ...] = ()

__all__ = ["set_mesh", "get_mesh", "dp_axes_active", "shard_map"]


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        # mid-range jax promoted shard_map to the top level before renaming
        # check_rep= to check_vma= — probe both spellings
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
        except TypeError:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
    from jax.experimental.shard_map import shard_map as legacy_sm
    return legacy_sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=check_vma)


def set_mesh(mesh, dp_axes: Tuple[str, ...]) -> None:
    global _MESH, _DP_AXES
    _MESH = mesh
    _DP_AXES = tuple(dp_axes)


def get_mesh():
    return _MESH


def dp_axes_active() -> Tuple[str, ...]:
    return _DP_AXES
