"""Ambient mesh context for model-internal shard_map blocks.

Model code (e.g. the shard_map MoE) needs the active mesh + data-parallel
axis names; launchers set them here.  Kept explicit (not jax's global mesh)
so models stay traceable without a mesh for single-device tests.
"""
from __future__ import annotations

from typing import Optional, Tuple

_MESH = None
_DP_AXES: Tuple[str, ...] = ()

__all__ = ["set_mesh", "get_mesh", "dp_axes_active"]


def set_mesh(mesh, dp_axes: Tuple[str, ...]) -> None:
    global _MESH, _DP_AXES
    _MESH = mesh
    _DP_AXES = tuple(dp_axes)


def get_mesh():
    return _MESH


def dp_axes_active() -> Tuple[str, ...]:
    return _DP_AXES
