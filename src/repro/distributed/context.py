"""Ambient mesh context for model-internal shard_map blocks.

Model code (e.g. the shard_map MoE) needs the active mesh + data-parallel
axis names; launchers set them here.  Kept explicit (not jax's global mesh)
so models stay traceable without a mesh for single-device tests.

Also hosts :func:`shard_map` — a version-compat wrapper over
``jax.shard_map`` (jax ≥ 0.5, ``check_vma=``) and
``jax.experimental.shard_map.shard_map`` (older jax, ``check_rep=``) —
and the process-identity helpers (:func:`process_info`,
:func:`process_tags`) that fleet launchers use to tag their per-process
:class:`~repro.core.session.TraceSession` so JSONL shards identify
themselves to :mod:`repro.obs.aggregate`.
"""
from __future__ import annotations

import os
import socket
from typing import Any, Dict, Optional, Tuple

import jax

_MESH = None
_DP_AXES: Tuple[str, ...] = ()

__all__ = ["set_mesh", "get_mesh", "dp_axes_active", "shard_map",
           "process_info", "process_tags", "shard_path"]


def shard_map(f, mesh, in_specs, out_specs, check_vma: bool = True):
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        # mid-range jax promoted shard_map to the top level before renaming
        # check_rep= to check_vma= — probe both spellings
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_vma=check_vma)
        except TypeError:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)
    from jax.experimental.shard_map import shard_map as legacy_sm
    return legacy_sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=check_vma)


def set_mesh(mesh, dp_axes: Tuple[str, ...]) -> None:
    global _MESH, _DP_AXES
    _MESH = mesh
    _DP_AXES = tuple(dp_axes)


def get_mesh():
    return _MESH


def dp_axes_active() -> Tuple[str, ...]:
    return _DP_AXES


def process_info() -> Dict[str, Any]:
    """This process's place in the fleet (single-process -> 0 of 1).

    ``REPRO_PROCESS_ID`` / ``REPRO_PROCESS_COUNT`` override the jax runtime
    view — multi-process *simulations* (one host, N launched processes,
    e.g. the two-process aggregation example) identify themselves that way
    without initializing jax.distributed.
    """
    env_idx = os.environ.get("REPRO_PROCESS_ID")
    if env_idx is not None:
        idx = int(env_idx)
        count = int(os.environ.get("REPRO_PROCESS_COUNT", idx + 1))
    else:
        try:
            idx, count = jax.process_index(), jax.process_count()
        except Exception:       # jax not initialized / very old API
            idx, count = 0, 1
    return {"host": socket.gethostname(), "process": int(idx),
            "process_count": int(count)}


def process_tags() -> Dict[str, Any]:
    """Session tags for this process: ``TraceSession(tags=process_tags())``.

    Every event the session emits then carries ``host``/``process`` in its
    ``meta`` — the shard identity :mod:`repro.obs.aggregate` merges by.
    """
    info = process_info()
    return {"host": info["host"], "process": info["process"]}


def shard_path(base: str) -> str:
    """Per-process JSONL shard path: ``trace.jsonl`` -> ``trace.p3.jsonl``.

    Identity function for a single-process fleet, so single-host CLIs can
    use it unconditionally.
    """
    info = process_info()
    if info["process_count"] <= 1:
        return base
    root, ext = os.path.splitext(base)
    return f"{root}.p{info['process']}{ext or '.jsonl'}"
