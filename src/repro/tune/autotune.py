"""The measurement->search->apply loop behind ``python -m repro.tune``.

Each candidate knob assignment is scored on small, representative workloads
run under a fresh :class:`~repro.core.TraceSession`:

* ``dma``   — a :class:`~repro.core.dma.HybridMover` put-sweep across sizes
  straddling the inline/direct switch (knob: ``dma_threshold_bytes``);
* ``serve`` — a smoke :class:`~repro.runtime.server.Server` greedy-decode
  batch (knob: ``tokens_per_launch``);
* ``train`` — a smoke :class:`~repro.runtime.trainer.Trainer` run (knob:
  ``steps_per_launch``, the graph capture granularity of the multi-step
  launcher);
* ``kv``    — shared-prefix continuous-batching traffic on the paged KV
  backend (knobs: ``kv_page_tokens``, ``prefill_chunk``); opt-in via
  ``--workloads``.

Every workload warms up first (compile + first dispatch) and measures only
the steady-state summary delta, because that is the regime a persisted
policy runs in.  Workload results are cached by the sub-assignment of knobs
they actually read, so coordinate descent never re-measures an unchanged
workload.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .env import EnvPreset, snapshot_env
from .objective import Metrics, Objective, metrics_from_summary
from .policy import Policy, activate_policy, save_policy
from .search import Knob, SearchResult, coordinate_descent

__all__ = ["WorkloadSpec", "KNOB_WORKLOADS", "default_knobs",
           "CandidateEvaluator", "tune"]

#: workload name -> the knobs its measurement depends on (the cache key).
KNOB_WORKLOADS: Dict[str, Tuple[str, ...]] = {
    "dma": ("dma_threshold_bytes",),
    "serve": ("tokens_per_launch",),
    "train": ("steps_per_launch",),
    # paged-KV serving path: page granularity and prefill chunking are
    # coupled (a chunk boundary lands mid-page or not), so one workload
    # measures both under shared-prefix continuous-batching traffic.
    "kv": ("kv_page_tokens", "prefill_chunk"),
}


@dataclasses.dataclass
class WorkloadSpec:
    """Sizes of the measurement workloads (smoke-scale by default)."""

    # serve; ``serve_mode="continuous"`` measures the knob under the
    # continuous-batching engine (queued Poisson traffic, per-slot decode)
    # instead of a static one-shot batch — the regime a serving policy
    # actually runs in.
    batch: int = 2
    prompt_len: int = 4
    new_tokens: int = 8
    max_seq: int = 64
    serve_mode: str = "oneshot"   # oneshot | continuous
    serve_requests: int = 6       # continuous mode: requests per measurement
    kv_prefix_len: int = 16       # kv workload: shared prefix tokens
    # train
    train_batch: int = 2
    train_seq: int = 32
    train_steps: int = 8          # measured steps; ladder values must divide
    # dma
    dma_sizes: Tuple[int, ...] = (256, 4096, 32 * 1024, 256 * 1024)
    dma_repeats: int = 3
    seed: int = 0

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def default_knobs(workloads: Sequence[str]) -> List[Knob]:
    """The exposed submission knobs, as discrete ladders, per workload."""
    from ..core.dma import INLINE_THRESHOLD_DEFAULT
    ladders = {
        "dma": (Knob("dma_threshold_bytes",
                     (0, 4 * 1024, INLINE_THRESHOLD_DEFAULT, 128 * 1024),
                     default=INLINE_THRESHOLD_DEFAULT),),
        "serve": (Knob("tokens_per_launch", (1, 2, 4, 8), default=1),),
        "train": (Knob("steps_per_launch", (1, 2, 4), default=1),),
        # page-size ladder must divide the workload max_seq (64); chunk 0
        # means whole-prompt prefill (the chunking-off baseline).
        "kv": (Knob("kv_page_tokens", (4, 8, 16, 32), default=16),
               Knob("prefill_chunk", (0, 4, 8, 16), default=0)),
    }
    return [k for w in workloads for k in ladders[w]]


class CandidateEvaluator:
    """Score one knob assignment across the enabled workloads.

    Callable with the :func:`~repro.tune.search.coordinate_descent` contract:
    ``evaluate(knobs) -> (score, info)``.  Per-workload measurements are
    cached by the knob values that workload reads.
    """

    def __init__(self, cfg: Any, spec: WorkloadSpec = WorkloadSpec(),
                 objective: Optional[Objective] = None,
                 workloads: Sequence[str] = ("dma", "serve", "train"),
                 log: Optional[Callable[[str], None]] = None) -> None:
        unknown = set(workloads) - set(KNOB_WORKLOADS)
        if unknown:
            raise ValueError(f"unknown workloads: {sorted(unknown)}")
        self.cfg = cfg
        self.spec = spec
        self.objective = objective or Objective()
        self.workloads = tuple(workloads)
        self._cache: Dict[Tuple, Metrics] = {}
        self._log = log or (lambda s: None)

    # -- workloads ---------------------------------------------------------
    def _measure_dma(self, knobs: Dict[str, Any]) -> Metrics:
        from ..core.dma import HybridMover
        from ..core.session import TraceSession
        spec = self.spec
        arrays = [np.arange(max(1, n), dtype=np.int64).astype(np.uint8)
                  for n in spec.dma_sizes]
        with TraceSession(name="tune_dma") as sess:
            mover = HybridMover(threshold=knobs["dma_threshold_bytes"],
                                session=sess)
            for x in arrays:                       # warm: compile inline path
                mover.put(x)
            before = sess.summary()
            for _ in range(spec.dma_repeats):
                for x in arrays:
                    mover.put(x)
            m = metrics_from_summary(
                sess.summary(), before,
                tokens=spec.dma_repeats * len(arrays))
        return m

    def _measure_serve(self, knobs: Dict[str, Any]) -> Metrics:
        if self.spec.serve_mode == "continuous":
            return self._measure_serve_continuous(knobs)
        from ..core.session import TraceSession
        from ..runtime.server import Request, Server
        spec = self.spec
        rng = np.random.default_rng(spec.seed)

        def requests() -> List[Request]:
            return [Request(i, rng.integers(
                        0, self.cfg.vocab_size,
                        size=spec.prompt_len).astype(np.int32),
                        max_new_tokens=spec.new_tokens)
                    for i in range(spec.batch)]

        with TraceSession(name="tune_serve") as sess:
            srv = Server(self.cfg, batch_size=spec.batch,
                         max_seq=spec.max_seq,
                         tokens_per_launch=knobs["tokens_per_launch"],
                         seed=spec.seed, session=sess)
            srv.serve(requests())                  # warm: compile + dispatch
            before = sess.summary()
            out = srv.serve(requests())
            m = metrics_from_summary(sess.summary(), before,
                                     tokens=out["new_tokens"])
        return m

    def _measure_serve_continuous(self, knobs: Dict[str, Any]) -> Metrics:
        """Score ``tokens_per_launch`` under continuous batching: seeded
        Poisson traffic drained synchronously (deterministic scheduling),
        steady-state summary delta after one warm-up replay."""
        from ..core.session import TraceSession
        from ..runtime.server import ContinuousBatchingServer
        from ..runtime.traffic import TrafficSpec, generate, replay
        spec = self.spec
        tspec = TrafficSpec(n_requests=spec.serve_requests, rate=1000.0,
                            prompt_lens=(spec.prompt_len,),
                            new_tokens=(spec.new_tokens,), seed=spec.seed)
        with TraceSession(name="tune_serve_cb") as sess:
            eng = ContinuousBatchingServer(
                self.cfg, batch_size=spec.batch, max_seq=spec.max_seq,
                tokens_per_launch=knobs["tokens_per_launch"],
                seed=spec.seed, session=sess)
            # warm: compiles prefill (per prompt length) + the slot decode
            replay(eng, generate(tspec, self.cfg.vocab_size),
                   realtime=False)
            before = sess.summary()
            _, out = replay(eng, generate(tspec, self.cfg.vocab_size),
                            realtime=False)
            m = metrics_from_summary(sess.summary(), before,
                                     tokens=out["new_tokens"])
        return m

    def _measure_kv(self, knobs: Dict[str, Any]) -> Metrics:
        """Score page size + prefill chunking on the paged backend under
        shared-prefix traffic — the regime where both knobs matter: page
        granularity sets how much of the common prefix is reusable, and
        the chunk bound trades prefill latency against decode stalls."""
        from ..core.session import TraceSession
        from ..runtime.server import ContinuousBatchingServer
        from ..runtime.traffic import TrafficSpec, generate, replay
        spec = self.spec
        tspec = TrafficSpec(n_requests=spec.serve_requests, rate=1000.0,
                            prompt_lens=(spec.prompt_len,),
                            new_tokens=(spec.new_tokens,), seed=spec.seed,
                            prefix_len=spec.kv_prefix_len)
        with TraceSession(name="tune_kv") as sess:
            # tokens_per_launch is pinned (not read from ``knobs``): it is
            # not in this workload's cache key, so reading it would serve
            # stale measurements when the serve workload tunes it.
            eng = ContinuousBatchingServer(
                self.cfg, batch_size=spec.batch, max_seq=spec.max_seq,
                tokens_per_launch=4,
                seed=spec.seed, session=sess, kv="paged",
                kv_page_tokens=int(knobs["kv_page_tokens"]),
                prefill_chunk=int(knobs["prefill_chunk"]))
            # warm: compiles the paged decode + extend kernels
            replay(eng, generate(tspec, self.cfg.vocab_size),
                   realtime=False)
            before = sess.summary()
            _, out = replay(eng, generate(tspec, self.cfg.vocab_size),
                            realtime=False)
            m = metrics_from_summary(sess.summary(), before,
                                     tokens=out["new_tokens"])
        return m

    def _measure_train(self, knobs: Dict[str, Any]) -> Metrics:
        from ..configs.shapes import ShapeConfig
        from ..core.session import TraceSession
        spec = self.spec
        from ..runtime.trainer import Trainer
        k = int(knobs["steps_per_launch"])
        shape = ShapeConfig("tune", spec.train_seq, spec.train_batch, "train")
        with TraceSession(name="tune_train") as sess:
            tr = Trainer(self.cfg, shape, steps_per_launch=k,
                         seed=spec.seed, session=sess)
            tr.train(k)                            # warm: one launch
            before = sess.summary()
            steps = max(k, (spec.train_steps // k) * k)
            tr.train(tr.step + steps)
            m = metrics_from_summary(sess.summary(), before, tokens=steps)
        return m

    _MEASURE = {"dma": _measure_dma, "serve": _measure_serve,
                "train": _measure_train, "kv": _measure_kv}

    # -- evaluation --------------------------------------------------------
    def measure(self, workload: str, knobs: Dict[str, Any]) -> Metrics:
        key = (workload,) + tuple(knobs[k] for k in KNOB_WORKLOADS[workload])
        if key not in self._cache:
            t0 = time.perf_counter()
            self._cache[key] = self._MEASURE[workload](self, knobs)
            self._log(f"    measured {key} in "
                      f"{time.perf_counter() - t0:.1f}s")
        return self._cache[key]

    def __call__(self, knobs: Dict[str, Any]
                 ) -> Tuple[float, Dict[str, Any]]:
        total = 0.0
        info: Dict[str, Any] = {}
        for w in self.workloads:
            if any(k not in knobs for k in KNOB_WORKLOADS[w]):
                continue
            m = self.measure(w, knobs)
            s = self.objective.score(m)
            total += s
            info[w] = {"score": s, **m.to_dict()}
        return total, info


def tune(arch: str, smoke: bool = True, rounds: int = 2,
         workloads: Sequence[str] = ("dma", "serve", "train"),
         spec: WorkloadSpec = WorkloadSpec(),
         objective: Optional[Objective] = None,
         env_preset: Optional[EnvPreset] = None,
         policy_dir: Optional[str] = None,
         log: Optional[Callable[[str], None]] = print,
         ) -> Tuple[Policy, SearchResult, str]:
    """Search the knob space for ``arch``; persist + activate the winner.

    Returns ``(policy, search_result, saved_path)``.  The policy's
    ``objective`` block records the before (all-defaults) and after (best)
    scores plus the full trial log, so the win is auditable without
    re-running the tuner.
    """
    from ..configs import ARCHS, SMOKE_ARCHS
    if env_preset is not None:
        env_preset.apply()
    import jax
    cfg = (SMOKE_ARCHS if smoke else ARCHS)[arch]
    objective = objective or Objective()
    knobs = default_knobs(workloads)
    evaluator = CandidateEvaluator(cfg, spec=spec, objective=objective,
                                   workloads=workloads, log=log)
    result = coordinate_descent(evaluator, knobs, max_rounds=rounds, log=log)
    # Key the policy by the config's own name (what Trainer/Server look up
    # via ``cfg.name``), not the registry key -- smoke registries alias
    # "gemma-2b" to a config named "gemma-smoke".
    policy = Policy(
        arch=getattr(cfg, "name", None) or arch,
        platform=jax.default_backend(),
        device_count=jax.device_count(),
        knobs=dict(result.best),
        objective={
            "before": result.start_score,
            "after": result.best_score,
            "improvement": result.improvement,
            "weights": dataclasses.asdict(objective.weights),
            "trials": [t.to_dict() for t in result.trials],
        },
        env={**snapshot_env(),
             **({"preset": env_preset.to_dict()} if env_preset else {})},
        meta={
            "arch_key": arch,
            "smoke": smoke,
            "rounds": result.rounds,
            "workloads": list(workloads),
            "workload_spec": spec.to_dict(),
            "created_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
        })
    path = save_policy(policy, policy_dir)
    activate_policy(policy)
    if log:
        log(f"policy saved: {path}")
        log(f"objective: before={result.start_score:.3e} "
            f"after={result.best_score:.3e} "
            f"({100 * result.improvement:.1f}% better), knobs={result.best}")
    return policy, result, path
