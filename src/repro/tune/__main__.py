"""CLI: search the exposed submission knobs and persist a policy.

    PYTHONPATH=src python -m repro.tune --arch gemma-2b \
        [--workloads dma,serve,train] [--rounds 2] [--full] \
        [--policy-dir results/policies] [--x64] [--host-devices N]

The environment preset (XLA flags, host device count, x64) is applied BEFORE
the first JAX initialization and recorded in the policy, Snippet-1 style.
After tuning, ``--verify`` (default) re-runs the serve workload with the
knobs left unset — exercising the auto-apply path Trainer/Server use — and
prints the TraceSession summary so the before/after objective is visible.
"""
from __future__ import annotations

import argparse
import json


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(prog="python -m repro.tune")
    ap.add_argument("--arch", default="gemma-2b")
    ap.add_argument("--full", action="store_true",
                    help="tune the full published config (default: smoke)")
    ap.add_argument("--rounds", type=int, default=2)
    ap.add_argument("--workloads", default="dma,serve,train",
                    help="comma-separated subset of dma,serve,train,kv "
                         "(kv tunes the paged backend's kv_page_tokens + "
                         "prefill_chunk; opt-in)")
    ap.add_argument("--policy-dir", default=None)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=64)
    ap.add_argument("--serve-mode", default="oneshot",
                    choices=("oneshot", "continuous"),
                    help="measure tokens_per_launch on a one-shot batch or "
                         "under the continuous-batching engine")
    ap.add_argument("--train-steps", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    # environment preset (applied before first JAX init)
    ap.add_argument("--x64", action="store_true")
    ap.add_argument("--host-devices", type=int, default=None)
    ap.add_argument("--xla-flags", default="")
    ap.add_argument("--platform", default=None)
    ap.add_argument("--no-verify", dest="verify", action="store_false")
    args = ap.parse_args(argv)

    from .env import EnvPreset
    preset = EnvPreset(host_device_count=args.host_devices,
                       xla_flags=args.xla_flags,
                       x64=args.x64 or None, platform=args.platform)
    preset.apply()

    from .autotune import WorkloadSpec, tune
    spec = WorkloadSpec(batch=args.batch, new_tokens=args.new_tokens,
                        max_seq=args.max_seq, train_steps=args.train_steps,
                        serve_mode=args.serve_mode, seed=args.seed)
    workloads = tuple(w for w in args.workloads.split(",") if w)
    policy, result, path = tune(
        args.arch, smoke=not args.full, rounds=args.rounds,
        workloads=workloads, spec=spec, env_preset=preset,
        policy_dir=args.policy_dir)

    if args.verify and "serve" in workloads:
        _verify(args, policy)


def _verify(args, policy) -> None:
    """Auto-apply check: a fresh Server with the knob unset picks up the
    persisted policy; its steady-state summary shows the tuned objective."""
    import numpy as np

    from ..configs import ARCHS, SMOKE_ARCHS
    from ..core.session import TraceSession
    from .objective import Objective, metrics_from_summary
    from ..runtime.server import Request, Server

    cfg = (SMOKE_ARCHS if not args.full else ARCHS)[args.arch]
    rng = np.random.default_rng(args.seed)

    def requests():
        return [Request(i, rng.integers(0, cfg.vocab_size,
                                        size=4).astype(np.int32),
                        max_new_tokens=args.new_tokens)
                for i in range(args.batch)]

    with TraceSession(name="tune_verify") as sess:
        srv = Server(cfg, batch_size=args.batch, max_seq=args.max_seq,
                     seed=args.seed, session=sess)   # tokens_per_launch unset
        srv.serve(requests())                        # warm
        before = sess.summary()
        out = srv.serve(requests())
        summary = sess.summary()
    m = metrics_from_summary(summary, before, tokens=out["new_tokens"])
    print(f"verify: auto-applied tokens_per_launch={srv.T} "
          f"(policy says {policy.knob('tokens_per_launch')})")
    print(f"verify: objective={Objective().score(m):.3e} s/token  "
          f"doorbells/token={m.doorbells_per_token:.3f}  "
          f"dispatch={m.dispatch_s * 1e3:.2f}ms")
    print("verify: session summary:")
    print(json.dumps({k: summary[k] for k in
                      ("by_kind", "dur_s_by_kind", "total_dispatch_s")},
                     indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
