"""Computation-environment presets for tuning runs.

A learned policy is only valid in the environment it was measured in — the
same lesson as the paper's careful pinning of driver/CUDA versions.  An
:class:`EnvPreset` captures the JAX environment knobs that change submission
behaviour (XLA flags, forced host device count, x64, platform), applies them
*before* measurement, and serializes into the policy JSON so a loader can
check (or re-create) the conditions a policy was learned under.

Style follows the bayespec ``config.py`` exemplar (SNIPPETS.md Snippet 1):
small, explicit helpers over ``os.environ`` / ``jax.config``.
"""
from __future__ import annotations

import dataclasses
import os
import warnings
from typing import Any, Dict, Optional

__all__ = ["EnvPreset", "snapshot_env"]


def _jax_initialized() -> bool:
    try:
        from jax._src import xla_bridge
        return bool(getattr(xla_bridge, "_backends", None))
    except Exception:  # pragma: no cover - private API moved
        return False


@dataclasses.dataclass(frozen=True)
class EnvPreset:
    """Environment knobs applied before a tuning (or tuned) run.

    ``host_device_count`` and ``xla_flags`` only take effect if applied
    before the first JAX initialization — :meth:`apply` warns otherwise
    instead of silently recording an environment that was never in force.
    """

    host_device_count: Optional[int] = None   # --xla_force_host_platform_device_count
    xla_flags: str = ""                       # extra XLA_FLAGS, space-separated
    x64: Optional[bool] = None                # jax_enable_x64
    platform: Optional[str] = None            # cpu | gpu | tpu

    def apply(self) -> None:
        """Apply the preset; must run before the first ``jax`` device use."""
        flags = []
        if self.host_device_count is not None:
            flags.append("--xla_force_host_platform_device_count="
                         f"{int(self.host_device_count)}")
        if self.xla_flags:
            flags.append(self.xla_flags)
        if flags:
            if _jax_initialized():
                warnings.warn(
                    "EnvPreset.apply() after JAX initialization: XLA flags "
                    "will not take effect for this process", RuntimeWarning)
            os.environ["XLA_FLAGS"] = " ".join(
                flags + [os.environ.get("XLA_FLAGS", "")]).strip()
        if self.x64 is not None or self.platform is not None:
            import jax
            if self.x64 is not None:
                jax.config.update("jax_enable_x64", bool(self.x64))
            if self.platform is not None:
                jax.config.update("jax_platform_name", self.platform)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "EnvPreset":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


def snapshot_env() -> Dict[str, Any]:
    """Record the effective environment a measurement ran under."""
    import jax
    return {
        "platform": jax.default_backend(),
        "device_count": jax.device_count(),
        "x64": bool(jax.config.read("jax_enable_x64")),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "jax_version": jax.__version__,
    }
