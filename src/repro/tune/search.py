"""Knob-space search: coordinate descent over discrete value ladders.

This is ``launch/hillclimb.py`` generalized: instead of one hand-labelled
(arch x shape x mesh) cell per invocation, the driver walks an explicit knob
space — each :class:`Knob` is an ordered ladder of candidate values — and
greedily descends one coordinate at a time until a full round makes no
improvement.  Evaluations are cached by knob assignment, so re-visiting a
configuration (common in coordinate descent) costs nothing; every evaluation
is kept as a :class:`Trial` so the search trajectory is auditable in the
persisted policy.

Also home to the override/spec parsing shared with the hillclimb CLI
(:func:`parse_value`, :func:`parse_spec`).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

__all__ = ["Knob", "Trial", "SearchResult", "coordinate_descent",
           "parse_value", "parse_spec"]


@dataclasses.dataclass(frozen=True)
class Knob:
    """One tunable: a name and the ordered ladder of values to consider."""

    name: str
    values: Tuple[Any, ...]
    default: Any = None

    def __post_init__(self) -> None:
        if not self.values:
            raise ValueError(f"knob {self.name!r} has no candidate values")

    def start(self) -> Any:
        return self.default if self.default is not None else self.values[0]


@dataclasses.dataclass
class Trial:
    """One evaluated knob assignment."""

    knobs: Dict[str, Any]
    score: float
    info: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return {"knobs": dict(self.knobs), "score": self.score,
                "info": dict(self.info)}


@dataclasses.dataclass
class SearchResult:
    best: Dict[str, Any]
    best_score: float
    start_score: float
    trials: List[Trial]
    rounds: int

    @property
    def improvement(self) -> float:
        """Fractional objective reduction vs the starting assignment."""
        if self.start_score <= 0:
            return 0.0
        return (self.start_score - self.best_score) / self.start_score


def coordinate_descent(
        evaluate: Callable[[Dict[str, Any]], Any],
        knobs: Sequence[Knob],
        start: Optional[Dict[str, Any]] = None,
        max_rounds: int = 3,
        log: Optional[Callable[[str], None]] = None) -> SearchResult:
    """Greedy per-coordinate descent over discrete ladders.

    ``evaluate`` maps a full knob assignment to a score (lower is better),
    or to a ``(score, info)`` pair — ``info`` rides along in the trial log.
    Each round sweeps every knob's full ladder with the others held at the
    incumbent; the search stops after a round with no improvement or after
    ``max_rounds`` rounds.
    """
    def _eval(assign: Dict[str, Any]) -> Tuple[float, Dict[str, Any]]:
        out = evaluate(dict(assign))
        if isinstance(out, tuple):
            score, info = out
        else:
            score, info = out, {}
        return float(score), dict(info)

    say = log or (lambda s: None)
    current = {k.name: k.start() for k in knobs}
    if start:
        current.update({k: v for k, v in start.items() if k in current})

    cache: Dict[Tuple, Tuple[float, Dict[str, Any]]] = {}
    trials: List[Trial] = []

    def _score(assign: Dict[str, Any]) -> float:
        key = tuple(assign[k.name] for k in knobs)
        if key not in cache:
            cache[key] = _eval(assign)
            trials.append(Trial(dict(assign), *cache[key]))
            say(f"  trial {assign} -> {cache[key][0]:.3e}")
        return cache[key][0]

    best_score = start_score = _score(current)
    rounds = 0
    for rounds in range(1, max_rounds + 1):
        improved = False
        for knob in knobs:
            for v in knob.values:
                if v == current[knob.name]:
                    continue
                cand = dict(current)
                cand[knob.name] = v
                s = _score(cand)
                if s < best_score:
                    best_score, current, improved = s, cand, True
            say(f"round {rounds}: {knob.name}={current[knob.name]} "
                f"score={best_score:.3e}")
        if not improved:
            break
    return SearchResult(best=current, best_score=best_score,
                        start_score=start_score, trials=trials, rounds=rounds)


# -- CLI spec parsing (shared with launch/hillclimb.py) --------------------

def parse_value(v: str) -> Any:
    """``"True"``/``"False"``/int/float/str, in that order."""
    if v in ("True", "False"):
        return v == "True"
    try:
        return int(v)
    except ValueError:
        pass
    try:
        return float(v)
    except ValueError:
        return v


def parse_spec(spec: str) -> Tuple[str, Any]:
    """Split a ``key:value`` spec on the LAST colon.

    Keys are free-form labels (HLO op paths, fusion tags) that may themselves
    contain colons — ``split(":")`` would shear them apart; only the value
    after the final colon is the numeric payload.
    """
    if ":" not in spec:
        raise ValueError(f"expected 'key:value', got {spec!r}")
    key, val = spec.rsplit(":", 1)
    return key, parse_value(val)
