"""Submission-policy autotuner: measurement -> search -> apply.

The paper's §7 closes on the observation that CUDA *hides* exactly the
submission knobs it should expose — the inline/direct DMA threshold, graph
granularity, launch batching — while Open MPI exposes its protocol thresholds
as tunables.  This repo exposes those knobs (``core/dma.py`` threshold,
``Server.tokens_per_launch``, trainer ``steps_per_launch`` / the graph
footprint law in ``core/graphs.py``); this package closes the loop:

* :mod:`repro.tune.objective` — scores a candidate from
  :meth:`TraceSession.summary` (host dispatch time, doorbells per token,
  transfer time/bandwidth);
* :mod:`repro.tune.search`    — coordinate-descent / hillclimb over discrete
  knob ladders (the generalization of ``launch/hillclimb.py``'s one-cell
  driver);
* :mod:`repro.tune.policy`    — the learned :class:`Policy` record, persisted
  as JSON per (model config, platform, device count) and auto-applied by
  ``Trainer``/``Server``/benchmarks;
* :mod:`repro.tune.env`       — environment presets (XLA flags, host device
  count, x64) applied before measurement so policies record the environment
  they were learned under;
* :mod:`repro.tune.autotune`  — the measurement workloads and the end-to-end
  ``tune()`` entry point behind ``python -m repro.tune``.
"""
from .env import EnvPreset, snapshot_env
from .objective import Metrics, Objective, ObjectiveWeights, metrics_from_summary
from .policy import (Policy, activate_policy, active_policy, clear_active_policy,
                     default_policy_dir, load_policy, load_policy_for,
                     policy_path, resolve_knob, save_policy)
from .search import Knob, SearchResult, Trial, coordinate_descent, parse_spec, parse_value

__all__ = [
    "EnvPreset", "snapshot_env",
    "Metrics", "Objective", "ObjectiveWeights", "metrics_from_summary",
    "Policy", "activate_policy", "active_policy", "clear_active_policy",
    "default_policy_dir", "load_policy", "load_policy_for", "policy_path",
    "resolve_knob", "save_policy",
    "Knob", "SearchResult", "Trial", "coordinate_descent", "parse_spec",
    "parse_value",
]
