"""Objective: score a candidate policy from TraceSession measurements.

The tuner's ground truth is the unified submission timeline: host dispatch
time, submission cycles (doorbells), and transfer cost, all read from
:meth:`repro.core.TraceSession.summary`.  :class:`Metrics` extracts the
relevant accumulators (supporting before/after deltas so warm-up and compile
can be excluded), and :class:`Objective` folds them into one scalar **host
cost per unit of useful work** — lower is better, and strictly monotone in
measured dispatch time (a property test pins this: a tuner whose objective
could *reward* dispatch time would happily tune the wrong way).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

__all__ = ["Metrics", "ObjectiveWeights", "Objective", "metrics_from_summary"]


@dataclasses.dataclass
class Metrics:
    """Submission-cost accumulators for one measured run (or run delta)."""

    dispatch_s: float = 0.0        # host time spent in dispatch events
    doorbells: int = 0             # submission cycles (dispatch-kind events)
    transfer_s: float = 0.0        # host time spent submitting transfers
    transfer_bytes: int = 0        # payload bytes moved by transfers
    compile_s: float = 0.0         # compile-kind time (reported, not scored)
    wall_s: float = 0.0
    tokens: int = 0                # useful work units (tokens, steps, puts)

    @property
    def doorbells_per_token(self) -> float:
        return self.doorbells / max(1, self.tokens)

    @property
    def transfer_bandwidth_gib_s(self) -> float:
        return self.transfer_bytes / max(self.transfer_s, 1e-12) / 2**30

    def __sub__(self, other: "Metrics") -> "Metrics":
        return Metrics(
            dispatch_s=self.dispatch_s - other.dispatch_s,
            doorbells=self.doorbells - other.doorbells,
            transfer_s=self.transfer_s - other.transfer_s,
            transfer_bytes=self.transfer_bytes - other.transfer_bytes,
            compile_s=self.compile_s - other.compile_s,
            wall_s=self.wall_s - other.wall_s,
            tokens=self.tokens - other.tokens)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["doorbells_per_token"] = self.doorbells_per_token
        d["transfer_bandwidth_gib_s"] = self.transfer_bandwidth_gib_s
        return d


def metrics_from_summary(summary: Dict[str, Any],
                         before: Optional[Dict[str, Any]] = None,
                         tokens: int = 0) -> Metrics:
    """Extract :class:`Metrics` from ``TraceSession.summary()`` output.

    ``before`` subtracts an earlier snapshot of the *same* session, so a
    caller can warm up (compile, first dispatch) and measure only the steady
    state — the regime a persisted policy will actually run in.
    """
    def _one(s: Dict[str, Any]) -> Metrics:
        kinds = s.get("by_kind", {})
        dur = s.get("dur_s_by_kind", {})
        payload = s.get("payload_by_kind", {})
        return Metrics(
            dispatch_s=float(dur.get("dispatch",
                                     s.get("total_dispatch_s", 0.0))),
            doorbells=int(kinds.get("dispatch", 0)),
            transfer_s=float(dur.get("transfer", 0.0)),
            transfer_bytes=int(payload.get("transfer", 0)),
            compile_s=float(dur.get("compile", 0.0)),
            wall_s=float(s.get("wall_s", 0.0)))

    m = _one(summary)
    if before is not None:
        m = m - _one(before)
    m.tokens = int(tokens)
    return m


@dataclasses.dataclass(frozen=True)
class ObjectiveWeights:
    """Cost model weights, all in host seconds (non-negative).

    ``doorbell_cost_s`` charges each submission cycle a fixed host-side
    overhead beyond its measured dispatch time — the paper's §6.3 point that
    submission *cycles*, not just submission *time*, bound small-kernel
    throughput (ring write + fence + scheduler wakeup are not all visible in
    the dispatch duration).
    """

    dispatch: float = 1.0
    transfer: float = 1.0
    doorbell_cost_s: float = 5e-6

    def __post_init__(self) -> None:
        if self.dispatch <= 0 or self.transfer < 0 or self.doorbell_cost_s < 0:
            raise ValueError("weights must be non-negative "
                             "(dispatch strictly positive)")


class Objective:
    """Scalar host cost per unit of work; lower is better."""

    def __init__(self, weights: ObjectiveWeights = ObjectiveWeights()) -> None:
        self.weights = weights

    def score(self, m: Metrics) -> float:
        w = self.weights
        cost = (w.dispatch * m.dispatch_s
                + w.transfer * m.transfer_s
                + w.doorbell_cost_s * m.doorbells)
        return cost / max(1, m.tokens)

    def score_summary(self, summary: Dict[str, Any],
                      before: Optional[Dict[str, Any]] = None,
                      tokens: int = 0) -> float:
        return self.score(metrics_from_summary(summary, before, tokens))
