"""Learned submission policies: the JSON record and its apply hooks.

A :class:`Policy` is the output of one autotuning run: the best knob values
found for one (model config, platform, device count) cell, together with the
before/after objective so the win is auditable, and the environment preset it
was measured under.  Policies persist as one JSON file per cell under a
policy directory (``REPRO_POLICY_DIR``, default ``results/policies``), keyed
``<arch>__<platform>__d<device_count>.json``.

Apply hooks: ``Trainer`` and ``Server`` call :func:`load_policy_for` when
their launch knob is left unset (``None``), and :func:`activate_policy` makes
the loaded policy ambient so knobs without an owner object — the
:class:`~repro.core.dma.HybridMover` inline/direct threshold — resolve
through :func:`resolve_knob`.  Explicit constructor arguments always win;
``REPRO_POLICY_DISABLE=1`` turns auto-loading off entirely.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
from typing import Any, Dict, Optional

__all__ = [
    "KNOB_NAMES",
    "Policy",
    "default_policy_dir",
    "policy_path",
    "save_policy",
    "load_policy",
    "load_policy_for",
    "activate_policy",
    "active_policy",
    "clear_active_policy",
    "resolve_knob",
]

#: The exposed submission knobs a policy may set — the ones the paper's §7
#: says CUDA hides (DMA protocol threshold, launch batching, graph
#: granularity).
KNOB_NAMES = ("dma_threshold_bytes", "tokens_per_launch", "steps_per_launch")

ENV_DIR = "REPRO_POLICY_DIR"
ENV_DISABLE = "REPRO_POLICY_DISABLE"
DEFAULT_DIR = os.path.join("results", "policies")


@dataclasses.dataclass
class Policy:
    """One tuned cell: knob values + the measurements that justify them."""

    arch: str
    platform: str
    device_count: int
    knobs: Dict[str, Any]
    objective: Dict[str, Any] = dataclasses.field(default_factory=dict)
    env: Dict[str, Any] = dataclasses.field(default_factory=dict)
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    version: int = 1

    def knob(self, name: str, default: Any = None) -> Any:
        return self.knobs.get(name, default)

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Policy":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


def default_policy_dir() -> str:
    """Resolved at call time so tests/processes can redirect via env."""
    return os.environ.get(ENV_DIR) or DEFAULT_DIR


def policy_path(arch: str, platform: str, device_count: int,
                policy_dir: Optional[str] = None) -> str:
    d = policy_dir or default_policy_dir()
    return os.path.join(d, f"{arch}__{platform}__d{int(device_count)}.json")


def save_policy(policy: Policy, policy_dir: Optional[str] = None) -> str:
    path = policy_path(policy.arch, policy.platform, policy.device_count,
                       policy_dir)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(policy.to_dict(), f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def load_policy(arch: str, platform: Optional[str] = None,
                device_count: Optional[int] = None,
                policy_dir: Optional[str] = None) -> Optional[Policy]:
    """Load the policy for (arch, platform, device_count), or None.

    Platform/device_count default to the current JAX runtime.  Falls back to
    any same-arch, same-platform policy (different device count) so a policy
    tuned on one host shape still provides sane defaults on another.
    """
    if os.environ.get(ENV_DISABLE):
        return None
    if platform is None or device_count is None:
        import jax
        platform = platform or jax.default_backend()
        device_count = device_count or jax.device_count()
    path = policy_path(arch, platform, device_count, policy_dir)
    if not os.path.exists(path):
        d = policy_dir or default_policy_dir()
        relaxed = sorted(glob.glob(
            os.path.join(d, f"{arch}__{platform}__d*.json")))
        if not relaxed:
            return None
        path = relaxed[0]
    try:
        with open(path) as f:
            return Policy.from_dict(json.load(f))
    except (OSError, ValueError, TypeError):
        return None


def load_policy_for(cfg: Any, policy_dir: Optional[str] = None,
                    activate: bool = True) -> Optional[Policy]:
    """Auto-apply hook: load (and activate) the policy for a model config."""
    arch = getattr(cfg, "name", None)
    if not arch:
        return None
    pol = load_policy(arch, policy_dir=policy_dir)
    if pol is not None and activate:
        activate_policy(pol)
    return pol


# -- ambient policy --------------------------------------------------------
# Knobs with an owner object (Trainer.k, Server.T) read the loaded policy
# directly; the DMA threshold has no owner until a HybridMover exists, so the
# most recently loaded/saved policy is kept ambient for resolve_knob().
_active: Optional[Policy] = None


def activate_policy(policy: Optional[Policy]) -> None:
    global _active
    _active = policy


def active_policy() -> Optional[Policy]:
    return _active


def clear_active_policy() -> None:
    activate_policy(None)


def resolve_knob(name: str, default: Any) -> Any:
    """Ambient-policy knob lookup (explicit values should bypass this)."""
    if _active is None or os.environ.get(ENV_DISABLE):
        return default
    return _active.knob(name, default)
