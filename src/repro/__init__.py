"""repro — command-stream visibility for JAX/TPU training & serving.

Reproduction + multi-pod extension of "Revealing NVIDIA Closed-Source Driver
Command Streams for CPU-GPU Runtime Behavior Insight" on the JAX/XLA stack.
See README.md / DESIGN.md / EXPERIMENTS.md.
"""
__version__ = "1.0.0"
