"""Oracle for the dma_copy kernel: identity."""
from __future__ import annotations

import jax

__all__ = ["dma_copy_ref"]


def dma_copy_ref(x: jax.Array) -> jax.Array:
    return x
