"""jit'd public wrappers for the dma_copy kernels."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import dma_copy_explicit, dma_copy_pipelined

__all__ = ["dma_copy"]


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


@partial(jax.jit, static_argnames=("mode", "block_rows"))
def dma_copy(x, mode: str = "pipelined", block_rows: int = 256):
    interp = not _on_tpu()
    if mode == "explicit":
        return dma_copy_explicit(x, block_rows=block_rows, interpret=interp)
    return dma_copy_pipelined(x, block_rows=block_rows, interpret=interp)
