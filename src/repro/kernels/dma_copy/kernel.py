"""Controlled DMA issuance — the paper's §5.3/§6.2 adapted to TPU.

The paper bypasses CUDA and programs the GPU copy engine directly by writing
DMA descriptors into the pushbuffer, measuring raw engine behaviour without
driver overhead.  The TPU analogue of "programming the copy engine" is
issuing explicit async HBM↔VMEM copies from a Pallas kernel:

* ``dma_copy_explicit`` keeps src/dst in ``ANY`` (HBM) memory space and
  moves each tile with ``pltpu.make_async_copy`` + DMA semaphores — the
  descriptors we write *are* the TPU's DMA commands (start/wait = the
  submit/semaphore protocol of §4.3);
* ``dma_copy_pipelined`` expresses the same transfer through BlockSpec
  pipelining, letting the Pallas pipeline emitter double-buffer the DMA —
  the "driver-chosen" path to compare against.

Sweeping tile sizes over both paths is the Figure-6 analogue: startup cost
vs saturation bandwidth of the copy path under explicit vs automatic
submission.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["dma_copy_pipelined", "dma_copy_explicit"]


def _pipelined_kernel(src_ref, dst_ref):
    dst_ref[...] = src_ref[...]


def dma_copy_pipelined(x: jax.Array, block_rows: int = 256,
                       interpret: bool = False) -> jax.Array:
    """[R, C] HBM→HBM copy, tiles auto-pipelined through VMEM."""
    R, C = x.shape
    block_rows = min(block_rows, R)
    assert R % block_rows == 0
    return pl.pallas_call(
        _pipelined_kernel,
        grid=(R // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, C), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, C), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, C), x.dtype),
        interpret=interpret,
    )(x)


def _explicit_kernel(src_hbm, dst_hbm, vmem, sem_in, sem_out,
                     *, block_rows: int):
    i = pl.program_id(0)
    rows = pl.dslice(i * block_rows, block_rows)
    copy_in = pltpu.make_async_copy(src_hbm.at[rows], vmem, sem_in)
    copy_in.start()
    copy_in.wait()
    copy_out = pltpu.make_async_copy(vmem, dst_hbm.at[rows], sem_out)
    copy_out.start()
    copy_out.wait()


def dma_copy_explicit(x: jax.Array, block_rows: int = 256,
                      interpret: bool = False) -> jax.Array:
    """[R, C] HBM→HBM copy with hand-written DMA descriptors."""
    R, C = x.shape
    block_rows = min(block_rows, R)
    assert R % block_rows == 0
    kernel = functools.partial(_explicit_kernel, block_rows=block_rows)
    return pl.pallas_call(
        kernel,
        grid=(R // block_rows,),
        in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
        out_specs=pl.BlockSpec(memory_space=pltpu.ANY),
        out_shape=jax.ShapeDtypeStruct((R, C), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_rows, C), x.dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        interpret=interpret,
    )(x)
