"""Pallas TPU kernels. Each kernel ships kernel.py (pl.pallas_call +
BlockSpec VMEM tiling), ops.py (jit'd wrapper, interpret on CPU), and
ref.py (pure-jnp oracle used by the shape/dtype sweep tests)."""
