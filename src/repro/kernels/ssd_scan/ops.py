"""jit'd public wrapper for the SSD scan kernel."""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax

from .kernel import ssd_scan_pallas

__all__ = ["ssd_scan"]


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


@partial(jax.jit, static_argnames=("chunk", "head_block"))
def ssd_scan(xh, dt, A, Bc, Cc, chunk: int = 128, head_block: int = 0
             ) -> Tuple[jax.Array, Optional[jax.Array]]:
    y = ssd_scan_pallas(xh, dt, A, Bc, Cc, chunk=chunk,
                        head_block=head_block, interpret=not _on_tpu())
    return y, None
