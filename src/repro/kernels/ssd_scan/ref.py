"""Pure-jnp oracle for the SSD scan kernel (re-exports the model's chunked
reference so the kernel and the model share one source of truth)."""
from __future__ import annotations

from ...models.mamba import ssd_chunked as ssd_scan_ref

__all__ = ["ssd_scan_ref"]
