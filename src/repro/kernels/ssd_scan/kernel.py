"""Mamba2 SSD (state-space duality) Pallas TPU kernel.

Chunked SSD maps onto the TPU as: intra-chunk quadratic term = MXU panels
([Q,N]×[N,Q] and [Q,Q]×[Q,P] matmuls), inter-chunk recurrence = a small
[H_blk, P, N] fp32 state carried in VMEM **scratch across grid steps**.
The grid is (B, H/H_blk, S/Q) with the chunk dimension innermost: Pallas
TPU grids execute sequentially, so the scratch state persists from chunk j
to j+1 and is reset at j == 0 — the TPU-idiomatic replacement for the GPU
version's inter-block shared-memory handoff.

VMEM per step ≈ Q·H_blk·P (x) + 2·Q·N (B,C) + H_blk·Q² (decay) + H_blk·P·N
(state) floats; Q=128..256, H_blk=4..8, P=64, N≤128 keeps this well under
the 16 MiB budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["ssd_scan_kernel", "ssd_scan_pallas"]


def ssd_scan_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_ref,
                    *, chunk: int):
    """One (batch, head-block, chunk) grid cell."""
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[...].astype(jnp.float32)            # [Q, Hb, P]
    dt = dt_ref[...].astype(jnp.float32)          # [Q, Hb]
    A = a_ref[...].astype(jnp.float32)            # [Hb]
    Bm = b_ref[...].astype(jnp.float32)           # [Q, N]
    Cm = c_ref[...].astype(jnp.float32)           # [Q, N]
    h = state_ref[...]                            # [Hb, P, N] fp32

    Q, Hb, P = x.shape
    xt = x.transpose(1, 0, 2)                     # [Hb, Q, P]
    dtt = dt.T                                    # [Hb, Q]

    dA = dtt * A[:, None]                         # [Hb, Q]  (<= 0)
    cum = jnp.cumsum(dA, axis=1)                  # [Hb, Q]
    tot = cum[:, -1]                              # [Hb]

    # ---- intra-chunk quadratic term ----
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q, Q]
    diff = cum[:, :, None] - cum[:, None, :]      # [Hb, Q, Q]
    qi = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where((qi >= ki)[None], jnp.exp(diff), 0.0)
    G = CB[None] * L * dtt[:, None, :]            # [Hb, Qq, Qk]
    y_intra = jax.lax.dot_general(
        G, xt, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)       # [Hb, Q, P]

    # ---- inter-chunk term (read carried state) ----
    # y_inter[h,q,p] = decay_q[h,q] * sum_n C[q,n] h[h,p,n]
    Ch = jax.lax.dot_general(
        jnp.broadcast_to(Cm[None], (Hb, Q, Cm.shape[1])), h,
        (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)       # [Hb, Q, P]
    y_inter = Ch * jnp.exp(cum)[:, :, None]

    y = (y_intra + y_inter).transpose(1, 0, 2)    # [Q, Hb, P]
    y_ref[...] = y.astype(y_ref.dtype)

    # ---- state update ----
    w = (dtt * jnp.exp(tot[:, None] - cum))       # [Hb, Q]
    xw = xt * w[:, :, None]                       # [Hb, Q, P]
    dstate = jax.lax.dot_general(
        xw, jnp.broadcast_to(Bm[None], (Hb, Q, Bm.shape[1])),
        (((1,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)       # [Hb, P, N]
    state_ref[...] = h * jnp.exp(tot)[:, None, None] + dstate


def ssd_scan_pallas(xh: jax.Array, dt: jax.Array, A: jax.Array,
                    Bc: jax.Array, Cc: jax.Array, chunk: int = 128,
                    head_block: int = 0, interpret: bool = False
                    ) -> jax.Array:
    """xh: [B,S,H,P]; dt: [B,S,H] (post-softplus); A: [H] (negative);
    Bc/Cc: [B,S,N].  Returns y: [B,S,H,P]."""
    B, S, H, P = xh.shape
    N = Bc.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0
    if head_block <= 0:
        head_block = next(h for h in (8, 4, 2, 1) if H % h == 0)
    grid = (B, H // head_block, S // chunk)

    kernel = functools.partial(ssd_scan_kernel, chunk=chunk)
    y = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, chunk, head_block, P),
                         lambda b, h, j: (b, j, h, 0)),
            pl.BlockSpec((None, chunk, head_block), lambda b, h, j: (b, j, h)),
            pl.BlockSpec((head_block,), lambda b, h, j: (h,)),
            pl.BlockSpec((None, chunk, N), lambda b, h, j: (b, j, 0)),
            pl.BlockSpec((None, chunk, N), lambda b, h, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, chunk, head_block, P),
                               lambda b, h, j: (b, j, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, S, H, P), xh.dtype),
        scratch_shapes=[pltpu.VMEM((head_block, P, N), jnp.float32)],
        interpret=interpret,
    )(xh, dt, A, Bc, Cc)
    return y
