"""Flash attention Pallas TPU kernel.

TPU adaptation of the paper-adjacent flash algorithm: the GPU version tiles
over SRAM with warp-level softmax; on TPU the tiles live in VMEM and the
MXU consumes [block_q, hd] × [hd, block_k] panels.  Grid = (B·H, S/block_q);
the kernel streams KV blocks with a fori_loop carrying the running
(max, sum, acc) in fp32 VREGs, skipping fully-masked future blocks via the
grid index — the causal-skip halves compute vs the masked dense loop.

Block sizes default to (128, 128): the MXU is 128×128 and hd ∈ {64,128,256}
for every assigned arch, so panels are hardware-aligned.  VMEM footprint per
step ≈ block_q·hd (q) + 2·block_k·hd (kv) + block_q·block_k (scores) floats —
well under the ~16 MiB/core VMEM budget for all supported shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention_kernel", "flash_attention_pallas"]

NEG_INF = float(jnp.finfo(jnp.float32).min)


def flash_attention_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int,
                           causal: bool, sm_scale: float, seq_len: int):
    """One (batch·head, q-block) grid cell."""
    q_idx = pl.program_id(1)
    block_q = q_ref.shape[0]
    hd = q_ref.shape[1]

    q = q_ref[...].astype(jnp.float32) * sm_scale      # [bq, hd]

    n_k_blocks = seq_len // block_k
    if causal:
        # last kv block that intersects this q block
        last = (q_idx + 1) * block_q // block_k
        n_iter = jnp.minimum(last + ((q_idx + 1) * block_q % block_k != 0),
                             n_k_blocks)
        n_iter = jnp.maximum(n_iter, 1)
    else:
        n_iter = n_k_blocks

    def body(j, carry):
        acc, m, l = carry
        k = pl.load(k_ref, (pl.dslice(j * block_k, block_k),
                            pl.dslice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(j * block_k, block_k),
                            pl.dslice(None))).astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        if causal:
            q_pos = q_idx * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            k_pos = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((block_q, hd), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, n_iter, body, (acc0, m0, l0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal: bool = True, block_q: int = 128,
                           block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q,k,v: [B, S, H, hd] (H already GQA-expanded) -> [B, S, H, hd]."""
    B, S, H, hd = q.shape
    assert k.shape == v.shape == (B, S, H, hd)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0

    # [B, S, H, hd] -> [B*H, S, hd]: each grid row owns one head's sequence
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)

    grid = (B * H, S // block_q)
    kernel = functools.partial(
        flash_attention_kernel, block_k=block_k, causal=causal,
        sm_scale=hd ** -0.5, seq_len=S)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, block_q, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, S, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, S, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, block_q, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
