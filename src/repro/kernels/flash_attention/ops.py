"""jit'd public wrapper for the flash attention kernel.

On CPU (tests, this container) the kernel body executes in interpret mode;
on TPU it compiles to Mosaic.  The oracle is ``ref.flash_attention_ref``.
"""
from __future__ import annotations

from functools import partial

import jax

from .kernel import flash_attention_pallas

__all__ = ["flash_attention"]


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


@partial(jax.jit, static_argnames=("causal", "block_q", "block_k"))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128):
    return flash_attention_pallas(q, k, v, causal=causal, block_q=block_q,
                                  block_k=block_k, interpret=not _on_tpu())
