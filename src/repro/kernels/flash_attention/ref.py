"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["flash_attention_ref"]


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True) -> jax.Array:
    """q,k,v: [B, S, H, hd] -> [B, S, H, hd], softmax in fp32."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    scale = hd ** -0.5
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        s = jnp.where(mask[None, None], s, jnp.finfo(jnp.float32).min)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v).astype(q.dtype)
