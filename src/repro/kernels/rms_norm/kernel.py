"""Fused RMSNorm Pallas TPU kernel.

Unfused, the norm is three HBM round-trips (square/mean, rsqrt-scale,
gain-multiply) over the residual stream — one of the flat-profile memory
terms left after the §Perf attention fixes.  Fused, each [block_rows, D]
tile is read once into VMEM, reduced in fp32 VREGs, scaled, and written
once: ~3× less norm traffic.

Grid = (rows / block_rows); D stays whole per tile (d_model ≤ 16 K for all
assigned archs → ≤ 128 KiB/row tile at bf16, comfortably inside VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rms_norm_kernel", "rms_norm_pallas"]


def rms_norm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)            # [block_rows, D]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    gain = 1.0 + s_ref[...].astype(jnp.float32)   # [D]
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * gain[None, :]).astype(o_ref.dtype)


def rms_norm_pallas(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
                    block_rows: int = 128, interpret: bool = False
                    ) -> jax.Array:
    """x: [..., D] -> [..., D] (rows flattened internally)."""
    orig_shape = x.shape
    D = x.shape[-1]
    rows = x.size // D
    x2 = x.reshape(rows, D)
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    kernel = functools.partial(rms_norm_kernel, eps=eps)
    out = pl.pallas_call(
        kernel,
        grid=(x2.shape[0] // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, scale)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
