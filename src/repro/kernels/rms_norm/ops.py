"""jit'd public wrapper for the fused RMSNorm kernel."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import rms_norm_pallas

__all__ = ["rms_norm_fused"]


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


@partial(jax.jit, static_argnames=("eps", "block_rows"))
def rms_norm_fused(x, scale, eps: float = 1e-6, block_rows: int = 128):
    return rms_norm_pallas(x, scale, eps=eps, block_rows=block_rows,
                           interpret=not _on_tpu())
