"""Mixture-of-Experts: top-k router + shared experts.

Two dispatch implementations:

* ``dense`` — every expert runs on every token, combined with router weights.
  Simple oracle; FLOPs are E/k× the useful work (the roofline
  ``model_flops_ratio`` exposes exactly this waste).
* ``sorted`` — capacity-bounded sort-based dispatch (MaxText-style): tokens
  are argsorted by assigned expert, each expert processes a static capacity
  C = ceil(S·k·cf / E) slice, outputs are scattered back with router weights.
  Per-batch-row dispatch keeps sorts local to the data shard (no collectives
  from the sort itself).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import Params

__all__ = ["init_moe", "moe_block", "moe_dense", "moe_sorted"]


def _act(g: jax.Array, act: str) -> jax.Array:
    return jax.nn.gelu(g) if act == "gelu" else jax.nn.silu(g)


def init_moe(key: jax.Array, cfg: ModelConfig, dtype) -> Params:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    s_in, s_ff = d ** -0.5, ff ** -0.5
    p = {
        "router": (jax.random.normal(kr, (d, E), jnp.float32) * s_in).astype(jnp.float32),
        "w_gate": (jax.random.normal(kg, (E, d, ff), jnp.float32) * s_in).astype(dtype),
        "w_up": (jax.random.normal(ku, (E, d, ff), jnp.float32) * s_in).astype(dtype),
        "w_down": (jax.random.normal(kd, (E, ff, d), jnp.float32) * s_ff).astype(dtype),
    }
    if cfg.n_shared_experts:
        Es = cfg.n_shared_experts
        k1, k2, k3 = jax.random.split(ks, 3)
        p["shared"] = {
            "w_gate": (jax.random.normal(k1, (Es, d, ff), jnp.float32) * s_in).astype(dtype),
            "w_up": (jax.random.normal(k2, (Es, d, ff), jnp.float32) * s_in).astype(dtype),
            "w_down": (jax.random.normal(k3, (Es, ff, d), jnp.float32) * s_ff).astype(dtype),
        }
    return p


def _shared_ffn(p: Params, x: jax.Array, act: str) -> jax.Array:
    # all shared experts always active: sum of their outputs
    g = jnp.einsum("bsd,edf->ebsf", x, p["w_gate"])
    u = jnp.einsum("bsd,edf->ebsf", x, p["w_up"])
    y = jnp.einsum("ebsf,efd->bsd", _act(g, act) * u, p["w_down"])
    return y


def _router(p: Params, cfg: ModelConfig, x: jax.Array
            ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (weights [B,S,k] fp32 normalized, ids [B,S,k], aux_loss)."""
    logits = (x.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, ids = jax.lax.top_k(probs, cfg.top_k)
    w = w / jnp.maximum(jnp.sum(w, axis=-1, keepdims=True), 1e-9)
    # load-balancing aux loss (Switch-style): E * sum_e f_e * p_e
    E = cfg.n_experts
    me = jnp.mean(probs, axis=(0, 1))
    one_hot = jax.nn.one_hot(ids[..., 0], E, dtype=jnp.float32)
    fe = jnp.mean(one_hot, axis=(0, 1))
    aux = E * jnp.sum(me * fe)
    return w, ids, aux


def moe_dense(p: Params, cfg: ModelConfig, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """Reference: all experts on all tokens."""
    w, ids, aux = _router(p, cfg, x)
    g = jnp.einsum("bsd,edf->ebsf", x, p["w_gate"])
    u = jnp.einsum("bsd,edf->ebsf", x, p["w_up"])
    y_e = jnp.einsum("ebsf,efd->ebsd", _act(g, cfg.act) * u, p["w_down"])
    mask = jax.nn.one_hot(ids, cfg.n_experts, dtype=jnp.float32)  # [B,S,k,E]
    comb = jnp.einsum("bske,bsk->ebs", mask, w).astype(x.dtype)
    y = jnp.einsum("ebs,ebsd->bsd", comb, y_e)
    if cfg.n_shared_experts:
        y = y + _shared_ffn(p["shared"], x, cfg.act)
    return y, aux


def _sorted_core(cfg: ModelConfig, x: jax.Array, w: jax.Array,
                 ids: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                 w_down: jax.Array) -> jax.Array:
    """Sort-based capacity dispatch given router outputs (no collectives).

    With ``w_gate/w_up`` holding a 1/TP slice of d_ff and ``w_down`` the
    matching slice of its contraction dim, the output is a PARTIAL sum —
    callers running under shard_map psum it over the model axis *after*
    the combine, so the reduction is over [B,S,D] rather than the k·cf×
    expanded [B,E,C,D] (the key collective saving; see EXPERIMENTS.md §Perf).
    """
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = max(1, int(S * k * cfg.capacity_factor / E))
    C = min(C, S)

    def dispatch_row(xr, wr, idr):
        # xr: [S, D]; wr/idr: [S, k]
        flat_ids = idr.reshape(-1)                        # [S*k]
        flat_w = wr.reshape(-1)
        tok_idx = jnp.repeat(jnp.arange(S), k)            # source token
        order = jnp.argsort(flat_ids, stable=True)        # group by expert
        sorted_ids = flat_ids[order]
        sorted_tok = tok_idx[order]
        sorted_w = flat_w[order]
        # position of each slot within its expert group
        counts = jnp.bincount(sorted_ids, length=E)       # [E]
        starts = jnp.cumsum(counts) - counts              # [E]
        within = jnp.arange(S * k) - starts[sorted_ids]   # rank in group
        keep = within < C                                 # capacity clip
        # gather tokens into [E, C, D]
        # dropped slots get an out-of-bounds index → discarded by mode="drop"
        slot = jnp.where(keep, sorted_ids * C + within, E * C)
        src = jnp.full((E * C,), S, jnp.int32)            # S = zero-pad row
        src = src.at[slot].set(sorted_tok.astype(jnp.int32), mode="drop")
        wtab = jnp.zeros((E * C,), jnp.float32)
        wtab = wtab.at[slot].add(sorted_w, mode="drop")
        xr_pad = jnp.concatenate([xr, jnp.zeros((1, D), xr.dtype)], axis=0)
        xe = xr_pad[src].reshape(E, C, D)
        return xe, src, wtab

    xe, src, wtab = jax.vmap(dispatch_row)(x, w, ids)      # [B,E,C,D] ...
    g = jnp.einsum("becd,edf->becf", xe, w_gate)
    u = jnp.einsum("becd,edf->becf", xe, w_up)
    ye = jnp.einsum("becf,efd->becd", _act(g, cfg.act) * u, w_down)

    def combine_row(ye_r, src_r, wtab_r):
        ye_flat = ye_r.reshape(E * C, D) * wtab_r[:, None].astype(ye_r.dtype)
        out = jnp.zeros((S + 1, D), ye_r.dtype)
        out = out.at[src_r].add(ye_flat, mode="drop")
        return out[:S]

    return jax.vmap(combine_row)(ye, src, wtab)


def moe_sorted(p: Params, cfg: ModelConfig, x: jax.Array
               ) -> Tuple[jax.Array, jax.Array]:
    """Sort-based capacity dispatch (XLA places the collectives)."""
    w, ids, aux = _router(p, cfg, x)
    y = _sorted_core(cfg, x, w, ids, p["w_gate"], p["w_up"], p["w_down"])
    if cfg.n_shared_experts:
        y = y + _shared_ffn(p["shared"], x, cfg.act)
    return y, aux


def moe_sorted_smap(p: Params, cfg: ModelConfig, x: jax.Array
                    ) -> Tuple[jax.Array, jax.Array]:
    """shard_map MoE: expert-internal TP with psum AFTER the combine.

    XLA's default partitioning all-reduces the k·cf×-expanded expert outputs
    [B,E,C,D] (and all-gathers the dispatch); doing the dispatch/combine on
    local shards and psumming the combined [B,S,D] cuts the MoE collective
    volume ~(k·cf + shared)× — the dominant term of the qwen2-moe train cell.
    Falls back to ``moe_sorted`` when no mesh context is active.
    """
    from ..distributed.context import dp_axes_active, get_mesh, shard_map
    mesh = get_mesh()
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return moe_sorted(p, cfg, x)
    from jax.sharding import PartitionSpec as P
    dp = dp_axes_active() or ("data",)
    dpa = dp if len(dp) > 1 else dp[0]
    w, ids, aux = _router(p, cfg, x)

    has_shared = bool(cfg.n_shared_experts)

    def body(xb, wb, idb, wg, wu, wd, sg, su, sd):
        y = _sorted_core(cfg, xb, wb, idb, wg, wu, wd)
        if has_shared:
            g = jnp.einsum("bsd,edf->ebsf", xb, sg)
            u = jnp.einsum("bsd,edf->ebsf", xb, su)
            y = y + jnp.einsum("ebsf,efd->bsd", _act(g, cfg.act) * u, sd)
        return jax.lax.psum(y, "model")

    shared = p.get("shared", None)
    if not has_shared:
        # zero-size replicated stand-ins keep one code path
        z = jnp.zeros((0, cfg.d_model, 1), x.dtype)
        sg = su = z
        sd = jnp.zeros((0, 1, cfg.d_model), x.dtype)
        shared_specs = (P(), P(), P())
    else:
        sg, su, sd = shared["w_gate"], shared["w_up"], shared["w_down"]
        shared_specs = (P(None, None, "model"), P(None, None, "model"),
                        P(None, "model", None))

    y = shard_map(
        body, mesh=mesh,
        in_specs=(P(dpa, None, None), P(dpa, None, None), P(dpa, None, None),
                  P(None, None, "model"), P(None, None, "model"),
                  P(None, "model", None)) + shared_specs,
        out_specs=P(dpa, None, None),
        check_vma=False,
    )(x, w, ids, p["w_gate"], p["w_up"], p["w_down"], sg, su, sd)
    return y, aux


def moe_block(p: Params, cfg: ModelConfig, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    if cfg.moe_impl == "dense":
        return moe_dense(p, cfg, x)
    if cfg.moe_impl == "sorted_smap":
        return moe_sorted_smap(p, cfg, x)
    return moe_sorted(p, cfg, x)
