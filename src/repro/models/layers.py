"""Shared model layers: norms, rotary embeddings, MLPs, embeddings.

Pure-JAX (no flax): parameters are pytrees of arrays, every init function is
``jax.eval_shape``-safe so the dry-run never allocates real weights.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig

__all__ = [
    "Params", "rms_norm", "init_rms_norm", "rotary", "apply_rope",
    "init_mlp", "mlp", "init_embedding", "embed", "unembed",
    "cross_entropy_loss", "sinusoidal_positions", "dtype_of",
]

Params = Dict[str, Any]


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------
def init_rms_norm(d: int, dtype) -> Params:
    return {"scale": jnp.zeros((d,), dtype)}


def rms_norm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    orig = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    # (1 + scale): zero-init scale gives identity — standard for stability
    return (x * (1.0 + p["scale"].astype(jnp.float32))).astype(orig)


# --------------------------------------------------------------------------
# rotary position embedding (NeoX half-split convention)
# --------------------------------------------------------------------------
def rotary(positions: jax.Array, head_dim: int,
           theta: float = 10000.0) -> Tuple[jax.Array, jax.Array]:
    """(sin, cos) of shape [..., head_dim/2] for integer positions."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: [..., S, H, hd]; sin/cos: [..., S, hd/2] (broadcast over heads)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    sin_b = sin[..., None, :]
    cos_b = cos[..., None, :]
    out = jnp.concatenate(
        [x1 * cos_b - x2 * sin_b, x2 * cos_b + x1 * sin_b], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> jax.Array:
    """Whisper-style sinusoidal table [n, d] (fp32)."""
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / (half - 1))
    ang = jnp.arange(n, dtype=jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------
# gated MLP (SwiGLU / GeGLU)
# --------------------------------------------------------------------------
def init_mlp(key: jax.Array, d: int, ff: int, dtype) -> Params:
    kg, ku, kd = jax.random.split(key, 3)
    s_in = d ** -0.5
    s_ff = ff ** -0.5
    return {
        "w_gate": (jax.random.normal(kg, (d, ff), jnp.float32) * s_in).astype(dtype),
        "w_up": (jax.random.normal(ku, (d, ff), jnp.float32) * s_in).astype(dtype),
        "w_down": (jax.random.normal(kd, (ff, d), jnp.float32) * s_ff).astype(dtype),
    }


def mlp(p: Params, x: jax.Array, act: str = "silu") -> jax.Array:
    g = x @ p["w_gate"]
    u = x @ p["w_up"]
    if act == "gelu":
        g = jax.nn.gelu(g)
    else:
        g = jax.nn.silu(g)
    return (g * u) @ p["w_down"]


# --------------------------------------------------------------------------
# embeddings / unembedding (vocab-sharded friendly)
# --------------------------------------------------------------------------
def init_embedding(key: jax.Array, vocab: int, d: int, dtype,
                   tie: bool = False) -> Params:
    ke, ko = jax.random.split(key)
    p = {"embed": (jax.random.normal(ke, (vocab, d), jnp.float32)
                   * (d ** -0.5)).astype(dtype)}
    if not tie:
        p["unembed"] = (jax.random.normal(ko, (d, vocab), jnp.float32)
                        * (d ** -0.5)).astype(dtype)
    return p


def embed(p: Params, tokens: jax.Array, scale: bool = False) -> jax.Array:
    x = jnp.take(p["embed"], tokens, axis=0)
    if scale:
        x = x * jnp.asarray(x.shape[-1] ** 0.5, x.dtype)
    return x


def unembed(p: Params, x: jax.Array) -> jax.Array:
    if "unembed" in p:
        return x @ p["unembed"]
    return x @ p["embed"].T


# --------------------------------------------------------------------------
# chunked cross-entropy loss (never materializes [B, S, V] at once)
# --------------------------------------------------------------------------
def cross_entropy_loss(emb_params: Params, x: jax.Array, labels: jax.Array,
                       chunk: int = 512, vocab_valid: Optional[int] = None
                       ) -> jax.Array:
    """Mean CE over [B, S] labels given final hidden states x: [B, S, D].

    Chunked over the sequence so the per-chunk logits [B, c, V] are the
    largest live buffer; padded vocab rows (>= vocab_valid) are masked.
    """
    B, S, D = x.shape
    chunk = min(chunk, S)
    n_chunks = S // chunk
    rem = S - n_chunks * chunk

    def chunk_loss(xc, yc):
        logits = unembed(emb_params, xc).astype(jnp.float32)
        if vocab_valid is not None and vocab_valid < logits.shape[-1]:
            neg = jnp.finfo(jnp.float32).min
            mask = jnp.arange(logits.shape[-1]) >= vocab_valid
            logits = jnp.where(mask, neg, logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yc[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - gold)

    if n_chunks > 0:
        xs = x[:, :n_chunks * chunk].reshape(B, n_chunks, chunk, D)
        ys = labels[:, :n_chunks * chunk].reshape(B, n_chunks, chunk)

        def body(acc, args):
            xc, yc = args
            return acc + chunk_loss(xc, yc), ()

        total, _ = jax.lax.scan(
            body, jnp.zeros((), jnp.float32),
            (jnp.moveaxis(xs, 1, 0), jnp.moveaxis(ys, 1, 0)))
    else:
        total = jnp.zeros((), jnp.float32)
    if rem:
        total = total + chunk_loss(x[:, n_chunks * chunk:],
                                   labels[:, n_chunks * chunk:])
    return total / (B * S)
