"""Jamba-style hybrid LM: Mamba/attention 1:7 interleave + MoE cadence.

Layers are grouped into periods of ``cfg.attn_every`` (8 for jamba); within a
period the sub-layer types are fixed (attention at ``attn_offset``, SSM
elsewhere; MoE replaces the MLP on every ``moe_every``-th layer).  The model
scans over periods: parameters are stacked [n_groups, ...] per sub-layer,
giving O(1) command footprint in depth like every other model here.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import (attention, decode_attention, init_attention,
                        init_kv_cache)
from .layers import (Params, cross_entropy_loss, dtype_of, embed,
                     init_embedding, init_mlp, init_rms_norm, mlp, rms_norm,
                     unembed)
from .mamba import (init_mamba, init_ssm_state, mamba_block,
                    mamba_decode_step)
from .moe import init_moe, moe_block
from .transformer import MOE_AUX_COEF

__all__ = ["HybridLM"]


class HybridLM:
    def __init__(self, cfg: ModelConfig, impl: str = "ref") -> None:
        self.constraint = lambda x: x
        assert cfg.attn_every > 0, "hybrid requires attn_every"
        assert cfg.n_layers % cfg.attn_every == 0
        self.cfg = cfg
        self.impl = impl
        self.period = cfg.attn_every
        self.n_groups = cfg.n_layers // self.period
        # fixed sub-layer plan within one period
        self.plan: List[Tuple[str, str]] = []
        for i in range(self.period):
            mixer = "attn" if cfg.is_attn_layer(i) else "mamba"
            ffn = "moe" if cfg.is_moe_layer(i) else "mlp"
            self.plan.append((mixer, ffn))
        self.n_attn_per_group = sum(1 for m, _ in self.plan if m == "attn")
        self.n_ssm_per_group = self.period - self.n_attn_per_group

    # ---- params ------------------------------------------------------------
    def _init_group(self, key: jax.Array) -> Params:
        cfg = self.cfg
        dtype = dtype_of(cfg)
        keys = jax.random.split(key, self.period)
        group: Dict[str, Params] = {}
        for i, (mixer, ffn) in enumerate(self.plan):
            k1, k2 = jax.random.split(keys[i])
            sub: Params = {"ln1": init_rms_norm(cfg.d_model, dtype),
                           "ln2": init_rms_norm(cfg.d_model, dtype)}
            if mixer == "attn":
                sub["attn"] = init_attention(k1, cfg, dtype)
            else:
                sub["mamba"] = init_mamba(k1, cfg, dtype)
            if ffn == "moe":
                sub["moe"] = init_moe(k2, cfg, dtype)
            else:
                sub["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, dtype)
            group[f"sub{i}"] = sub
        return group

    def init_params(self, key: jax.Array) -> Params:
        cfg = self.cfg
        dtype = dtype_of(cfg)
        k_emb, k_layers = jax.random.split(key)
        gkeys = jax.random.split(k_layers, self.n_groups)
        groups = jax.vmap(self._init_group)(gkeys)
        return {
            "emb": init_embedding(k_emb, cfg.vocab_padded, cfg.d_model,
                                  dtype, cfg.tie_embeddings),
            "groups": groups,
            "final_norm": init_rms_norm(cfg.d_model, dtype),
        }

    # ---- forward -------------------------------------------------------------
    def _group_forward(self, gp: Params, x: jax.Array, mode: str
                       ) -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        aux_total = jnp.zeros((), jnp.float32)
        for i, (mixer, ffn) in enumerate(self.plan):
            sub = gp[f"sub{i}"]
            h = rms_norm(sub["ln1"], x)
            if mixer == "attn":
                x = x + attention(sub["attn"], cfg, h, impl=self.impl)
            else:
                x = x + mamba_block(sub["mamba"], cfg, h, self.impl)
            h = rms_norm(sub["ln2"], x)
            if ffn == "moe":
                m, aux = moe_block(sub["moe"], cfg, h)
                aux_total = aux_total + aux
            else:
                m = mlp(sub["mlp"], h, cfg.act)
            x = x + m
        return x, aux_total / self.period

    def hidden_states(self, params: Params, tokens: jax.Array,
                      mode: str = "train") -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        x = embed(params["emb"], tokens, cfg.embed_scale)

        def scan_fn(carry, gp):
            y, aux = self._group_forward(gp, carry, mode)
            return self.constraint(y), aux

        if cfg.remat and mode == "train":
            scan_fn = jax.checkpoint(scan_fn)
        x, auxs = jax.lax.scan(scan_fn, self.constraint(x), params["groups"])
        return rms_norm(params["final_norm"], x), jnp.mean(auxs)

    def loss(self, params: Params, batch: Dict[str, jax.Array]
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        x, aux = self.hidden_states(params, batch["tokens"], mode="train")
        ce = cross_entropy_loss(params["emb"], x, batch["labels"],
                                cfg.loss_chunk, vocab_valid=cfg.vocab_size)
        return ce + MOE_AUX_COEF * aux, {"ce": ce, "aux": aux}

    # ---- serving ----------------------------------------------------------------
    def init_decode_state(self, batch: int, max_seq: int) -> Params:
        cfg = self.cfg
        dtype = dtype_of(cfg)
        kv = init_kv_cache(cfg, batch, max_seq, dtype,
                           n_layers=self.n_groups * self.n_attn_per_group)
        # reshape leading dim to [n_groups, n_attn_per_group]
        kv["k"] = kv["k"].reshape((self.n_groups, self.n_attn_per_group)
                                  + kv["k"].shape[1:])
        kv["v"] = kv["v"].reshape((self.n_groups, self.n_attn_per_group)
                                  + kv["v"].shape[1:])
        ssm = init_ssm_state(cfg, batch, dtype,
                             n_layers=self.n_groups * self.n_ssm_per_group)
        ssm = {k: v.reshape((self.n_groups, self.n_ssm_per_group) + v.shape[1:])
               for k, v in ssm.items()}
        return {"k": kv["k"], "v": kv["v"], "length": kv["length"],
                "ssm": ssm}

    def prefill(self, params: Params, tokens: jax.Array, max_seq: int
                ) -> Tuple[Params, jax.Array]:
        # dry-run prefill lowers the full forward; cache assembly reuses
        # the decode state shape (zero-filled here, filled by the server).
        B, S = tokens.shape
        x, _ = self.hidden_states(params, tokens, mode="prefill")
        logits = unembed(params["emb"], x[:, -1:, :])
        state = self.init_decode_state(B, max_seq)
        state["length"] = jnp.asarray(S, jnp.int32)
        return state, logits

    def decode_step(self, params: Params, state: Params, tokens: jax.Array
                    ) -> Tuple[Params, jax.Array]:
        cfg = self.cfg
        x = embed(params["emb"], tokens, cfg.embed_scale)
        length = state["length"]

        def scan_fn(carry, inp):
            gp, kc, vc, ssm = inp
            x = carry
            ai = 0
            si = 0
            new_k, new_v, new_ssm = [], [], []
            for i, (mixer, ffn) in enumerate(self.plan):
                sub = gp[f"sub{i}"]
                h_in = rms_norm(sub["ln1"], x)
                if mixer == "attn":
                    a, k1, v1 = decode_attention(
                        sub["attn"], cfg, h_in, kc[ai], vc[ai], length)
                    new_k.append(k1)
                    new_v.append(v1)
                    x = x + a
                    ai += 1
                else:
                    st = jax.tree_util.tree_map(lambda a: a[si], ssm)
                    dx, st = mamba_decode_step(sub["mamba"], cfg, h_in, st)
                    new_ssm.append(st)
                    x = x + dx
                    si += 1
                h2 = rms_norm(sub["ln2"], x)
                if ffn == "moe":
                    m, _ = moe_block(sub["moe"], cfg, h2)
                else:
                    m = mlp(sub["mlp"], h2, cfg.act)
                x = x + m
            stacked_ssm = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *new_ssm)
            return x, (jnp.stack(new_k), jnp.stack(new_v), stacked_ssm)

        x, (nk, nv, nssm) = jax.lax.scan(
            scan_fn, x,
            (params["groups"], state["k"], state["v"], state["ssm"]))
        x = rms_norm(params["final_norm"], x)
        logits = unembed(params["emb"], x)
        return {"k": nk, "v": nv, "ssm": nssm,
                "length": length + 1}, logits
