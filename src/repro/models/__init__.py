"""Model zoo: pure-JAX pytree models with scan-over-layers."""
from __future__ import annotations

from typing import Any

from ..configs.base import ModelConfig
from .encdec import EncDecLM
from .hybrid import HybridLM
from .ssm import MambaLM
from .transformer import TransformerLM
from .vlm import VlmLM

__all__ = ["get_model", "TransformerLM", "MambaLM", "HybridLM", "EncDecLM",
           "VlmLM"]


def get_model(cfg: ModelConfig, impl: str = "ref") -> Any:
    if cfg.family in ("dense", "moe"):
        return TransformerLM(cfg, impl)
    if cfg.family == "ssm":
        return MambaLM(cfg, impl)
    if cfg.family == "hybrid":
        return HybridLM(cfg, impl)
    if cfg.family == "audio":
        return EncDecLM(cfg, impl)
    if cfg.family == "vlm":
        return VlmLM(cfg, impl)
    raise ValueError(f"unknown family {cfg.family!r}")
