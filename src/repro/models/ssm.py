"""Mamba2 language model (attention-free): scan over SSD blocks."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import (Params, cross_entropy_loss, dtype_of, embed,
                     init_embedding, init_rms_norm, rms_norm, unembed)
from .mamba import (init_mamba, init_ssm_state, mamba_block,
                    mamba_decode_step)

__all__ = ["MambaLM"]


def init_block(key: jax.Array, cfg: ModelConfig, dtype) -> Params:
    return {
        "ln": init_rms_norm(cfg.d_model, dtype),
        "mamba": init_mamba(key, cfg, dtype),
    }


class MambaLM:
    def __init__(self, cfg: ModelConfig, impl: str = "ref") -> None:
        self.cfg = cfg
        self.impl = impl
        self.constraint = lambda x: x

    def init_params(self, key: jax.Array) -> Params:
        cfg = self.cfg
        dtype = dtype_of(cfg)
        k_emb, k_layers = jax.random.split(key)
        layer_keys = jax.random.split(k_layers, cfg.n_layers)
        layers = jax.vmap(lambda k: init_block(k, cfg, dtype))(layer_keys)
        return {
            "emb": init_embedding(k_emb, cfg.vocab_padded, cfg.d_model,
                                  dtype, cfg.tie_embeddings),
            "layers": layers,
            "final_norm": init_rms_norm(cfg.d_model, dtype),
        }

    def hidden_states(self, params: Params, tokens: jax.Array,
                      mode: str = "train") -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        x = embed(params["emb"], tokens, cfg.embed_scale)

        def scan_fn(carry, lp):
            y = carry + mamba_block(lp["mamba"], cfg,
                                    rms_norm(lp["ln"], carry), self.impl)
            return self.constraint(y), ()

        if cfg.remat and mode == "train":
            scan_fn = jax.checkpoint(scan_fn)
        x, _ = jax.lax.scan(scan_fn, self.constraint(x), params["layers"])
        return rms_norm(params["final_norm"], x), jnp.zeros((), jnp.float32)

    def loss(self, params: Params, batch: Dict[str, jax.Array]
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        x, _ = self.hidden_states(params, batch["tokens"], mode="train")
        ce = cross_entropy_loss(params["emb"], x, batch["labels"],
                                self.cfg.loss_chunk,
                                vocab_valid=self.cfg.vocab_size)
        return ce, {"ce": ce}

    # ---- serving ---------------------------------------------------------
    def init_decode_state(self, batch: int, max_seq: int) -> Params:
        # SSM state is O(1) in sequence length — max_seq is irrelevant,
        # which is exactly why this family runs long_500k.
        del max_seq
        return init_ssm_state(self.cfg, batch, dtype_of(self.cfg))

    def prefill(self, params: Params, tokens: jax.Array, max_seq: int
                ) -> Tuple[Params, jax.Array]:
        cfg = self.cfg
        B, S = tokens.shape
        x = embed(params["emb"], tokens, cfg.embed_scale)

        def scan_fn(carry, lp):
            y = carry + mamba_block(lp["mamba"], cfg,
                                    rms_norm(lp["ln"], carry), self.impl)
            return y, ()

        x, _ = jax.lax.scan(scan_fn, x, params["layers"])
        x = rms_norm(params["final_norm"], x)
        logits = unembed(params["emb"], x[:, -1:, :])
        # NOTE: the ref prefill recomputes final states per layer only when
        # serving continues; for the dry-run shapes the decode state is
        # initialized fresh (prefill_32k lowers the forward itself).
        state = self.init_decode_state(B, max_seq)
        return state, logits

    def decode_step(self, params: Params, state: Params, tokens: jax.Array
                    ) -> Tuple[Params, jax.Array]:
        cfg = self.cfg
        x = embed(params["emb"], tokens, cfg.embed_scale)

        def scan_fn(carry, inp):
            lp, st = inp
            dx, st = mamba_decode_step(
                lp["mamba"], cfg, rms_norm(lp["ln"], carry), st)
            return carry + dx, st

        x, new_state = jax.lax.scan(scan_fn, x, (params["layers"], state))
        x = rms_norm(params["final_norm"], x)
        logits = unembed(params["emb"], x)
        return new_state, logits
