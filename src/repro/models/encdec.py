"""Whisper-style encoder-decoder backbone.

The conv audio frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed frame embeddings [B, S_enc, d_model] (what the two conv layers
would emit).  Encoder: non-causal self-attention, sinusoidal positions.
Decoder: causal self-attention + cross-attention, learned positions.

Decode serves one token against a self-attention KV cache of the assigned
seq_len and a fixed-length cross-attention KV (CROSS_LEN=1500 — Whisper's
30 s encoder output; documented adaptation in DESIGN.md).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import (attention, decode_attention, init_attention,
                        init_kv_cache)
from .layers import (Params, cross_entropy_loss, dtype_of, embed,
                     init_embedding, init_mlp, init_rms_norm, mlp, rms_norm,
                     sinusoidal_positions, unembed)

__all__ = ["EncDecLM", "CROSS_LEN"]

CROSS_LEN = 1500  # whisper encoder output length (30 s of audio)


def _init_enc_block(key: jax.Array, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": init_rms_norm(cfg.d_model, dtype),
        "attn": init_attention(k1, cfg, dtype),
        "ln2": init_rms_norm(cfg.d_model, dtype),
        "mlp": init_mlp(k2, cfg.d_model, cfg.d_ff, dtype),
    }


def _init_dec_block(key: jax.Array, cfg: ModelConfig, dtype) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": init_rms_norm(cfg.d_model, dtype),
        "attn": init_attention(k1, cfg, dtype),
        "ln_x": init_rms_norm(cfg.d_model, dtype),
        "xattn": init_attention(k2, cfg, dtype),
        "ln2": init_rms_norm(cfg.d_model, dtype),
        "mlp": init_mlp(k3, cfg.d_model, cfg.d_ff, dtype),
    }


class EncDecLM:
    def __init__(self, cfg: ModelConfig, impl: str = "ref") -> None:
        assert cfg.is_encoder_decoder
        self.cfg = cfg
        self.impl = impl
        self.constraint = lambda x: x

    def init_params(self, key: jax.Array) -> Params:
        cfg = self.cfg
        dtype = dtype_of(cfg)
        k_emb, k_enc, k_dec, k_pos = jax.random.split(key, 4)
        enc_keys = jax.random.split(k_enc, cfg.n_enc_layers)
        dec_keys = jax.random.split(k_dec, cfg.n_layers)
        return {
            "emb": init_embedding(k_emb, cfg.vocab_padded, cfg.d_model,
                                  dtype, cfg.tie_embeddings),
            "pos_dec": (jax.random.normal(
                k_pos, (cfg.max_position, cfg.d_model), jnp.float32)
                * 0.01).astype(dtype),
            "encoder": jax.vmap(lambda k: _init_enc_block(k, cfg, dtype))(enc_keys),
            "decoder": jax.vmap(lambda k: _init_dec_block(k, cfg, dtype))(dec_keys),
            "enc_norm": init_rms_norm(cfg.d_model, dtype),
            "final_norm": init_rms_norm(cfg.d_model, dtype),
        }

    # ---- encoder -----------------------------------------------------------
    def encode(self, params: Params, frames: jax.Array, mode: str = "train"
               ) -> jax.Array:
        """frames: [B, S_enc, D] stub frontend embeddings."""
        cfg = self.cfg
        S = frames.shape[1]
        pos = sinusoidal_positions(S, cfg.d_model).astype(frames.dtype)
        x = frames + pos[None]

        def scan_fn(carry, lp):
            h = attention(lp["attn"], cfg, rms_norm(lp["ln1"], carry),
                          causal=False, impl=self.impl)
            y = carry + h
            y = y + mlp(lp["mlp"], rms_norm(lp["ln2"], y), cfg.act)
            return self.constraint(y), ()

        if cfg.remat and mode == "train":
            scan_fn = jax.checkpoint(scan_fn)
        x, _ = jax.lax.scan(scan_fn, x, params["encoder"])
        return rms_norm(params["enc_norm"], x)

    # ---- decoder ----------------------------------------------------------
    def decode_train(self, params: Params, tokens: jax.Array,
                     enc_out: jax.Array, mode: str = "train") -> jax.Array:
        cfg = self.cfg
        B, S = tokens.shape
        x = embed(params["emb"], tokens) + params["pos_dec"][None, :S]

        def scan_fn(carry, lp):
            y = carry + attention(lp["attn"], cfg,
                                  rms_norm(lp["ln1"], carry), impl=self.impl)
            # cross-attention: K/V from encoder output
            kx = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wk"])
            vx = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wv"])
            y = y + attention(lp["xattn"], cfg, rms_norm(lp["ln_x"], y),
                              causal=False, impl=self.impl,
                              kv_override=(kx, vx))
            y = y + mlp(lp["mlp"], rms_norm(lp["ln2"], y), cfg.act)
            return self.constraint(y), ()

        if cfg.remat and mode == "train":
            scan_fn = jax.checkpoint(scan_fn)
        x, _ = jax.lax.scan(scan_fn, self.constraint(x), params["decoder"])
        return rms_norm(params["final_norm"], x)

    def loss(self, params: Params, batch: Dict[str, jax.Array]
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        x = self.decode_train(params, batch["tokens"], enc_out)
        ce = cross_entropy_loss(params["emb"], x, batch["labels"],
                                cfg.loss_chunk, vocab_valid=cfg.vocab_size)
        return ce, {"ce": ce}

    # ---- serving ------------------------------------------------------------
    def init_decode_state(self, batch: int, max_seq: int) -> Params:
        cfg = self.cfg
        dtype = dtype_of(cfg)
        self_kv = init_kv_cache(cfg, batch, max_seq, dtype)
        cross_kv = init_kv_cache(cfg, batch, CROSS_LEN, dtype)
        return {"k": self_kv["k"], "v": self_kv["v"],
                "xk": cross_kv["k"], "xv": cross_kv["v"],
                "length": self_kv["length"]}

    def prefill(self, params: Params, frames: jax.Array, tokens: jax.Array,
                max_seq: int) -> Tuple[Params, jax.Array]:
        cfg = self.cfg
        B = tokens.shape[0]
        enc_out = self.encode(params, frames, mode="prefill")
        x = self.decode_train(params, tokens, enc_out, mode="prefill")
        logits = unembed(params["emb"], x[:, -1:, :])
        state = self.init_decode_state(B, max_seq)
        state["length"] = jnp.asarray(tokens.shape[1], jnp.int32)
        return state, logits

    def decode_step(self, params: Params, state: Params, tokens: jax.Array
                    ) -> Tuple[Params, jax.Array]:
        cfg = self.cfg
        length = state["length"]
        x = embed(params["emb"], tokens) + params["pos_dec"][length][None, None]

        def scan_fn(carry, inp):
            lp, kc, vc, xk, xv = inp
            y, kc, vc = decode_attention(
                lp["attn"], cfg, rms_norm(lp["ln1"], carry), kc, vc, length)
            y = carry + y
            # cross attention against precomputed (static) cross KV
            h = rms_norm(lp["ln_x"], y)
            q = jnp.einsum("bsd,dhk->bshk", h, lp["xattn"]["wq"])
            n_rep = cfg.n_heads_padded // cfg.n_kv_heads
            B = q.shape[0]
            q_ = q.reshape(B, cfg.n_kv_heads, n_rep, cfg.hd)
            s = jnp.einsum("bgrd,bsgd->bgrs", q_, xk).astype(jnp.float32)
            s = s * (cfg.hd ** -0.5)
            pr = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bgrs,bsgd->bgrd", pr.astype(xv.dtype), xv)
            o = o.reshape(B, 1, cfg.n_heads_padded, cfg.hd)
            y = y + jnp.einsum("bshk,hkd->bsd", o, lp["xattn"]["wo"])
            y = y + mlp(lp["mlp"], rms_norm(lp["ln2"], y), cfg.act)
            return y, (kc, vc)

        x, (nk, nv) = jax.lax.scan(
            scan_fn, x, (params["decoder"], state["k"], state["v"],
                         state["xk"], state["xv"]))
        x = rms_norm(params["final_norm"], x)
        logits = unembed(params["emb"], x)
        return {"k": nk, "v": nv, "xk": state["xk"], "xv": state["xv"],
                "length": length + 1}, logits
