"""Decoder-only transformer LM (dense and MoE) with scan-over-layers.

The layer stack is a single ``lax.scan`` over stacked per-layer parameters —
the command footprint (compiled HLO size) is O(1) in depth, which is the
paper's CUDA-Graph lesson applied to the compile path (see core/graphs.py).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import (attention, decode_attention, extend_attention,
                        init_attention, init_kv_cache)
from .layers import (Params, cross_entropy_loss, dtype_of, embed,
                     init_embedding, init_mlp, init_rms_norm, mlp, rms_norm,
                     unembed)
from .moe import init_moe, moe_block

__all__ = ["TransformerLM"]

MOE_AUX_COEF = 0.01


def init_block(key: jax.Array, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": init_rms_norm(cfg.d_model, dtype),
        "attn": init_attention(k1, cfg, dtype),
        "ln2": init_rms_norm(cfg.d_model, dtype),
    }
    if cfg.n_experts:
        p["moe"] = init_moe(k2, cfg, dtype)
    else:
        p["mlp"] = init_mlp(k2, cfg.d_model, cfg.d_ff, dtype)
    return p


def block_forward(p: Params, cfg: ModelConfig, x: jax.Array,
                  positions: Optional[jax.Array], impl: str = "ref"
                  ) -> Tuple[jax.Array, jax.Array]:
    a = attention(p["attn"], cfg, rms_norm(p["ln1"], x), positions, impl=impl)
    x = x + a
    h = rms_norm(p["ln2"], x)
    if cfg.n_experts:
        m, aux = moe_block(p["moe"], cfg, h)
    else:
        m, aux = mlp(p["mlp"], h, cfg.act), jnp.zeros((), jnp.float32)
    return x + m, aux


def block_decode(p: Params, cfg: ModelConfig, x: jax.Array,
                 k_cache: jax.Array, v_cache: jax.Array, length: jax.Array
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    a, k_cache, v_cache = decode_attention(
        p["attn"], cfg, rms_norm(p["ln1"], x), k_cache, v_cache, length)
    x = x + a
    h = rms_norm(p["ln2"], x)
    if cfg.n_experts:
        m, _ = moe_block(p["moe"], cfg, h)
    else:
        m = mlp(p["mlp"], h, cfg.act)
    return x + m, k_cache, v_cache


def block_extend(p: Params, cfg: ModelConfig, x: jax.Array,
                 k_cache: jax.Array, v_cache: jax.Array, start: jax.Array
                 ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    a, k_cache, v_cache = extend_attention(
        p["attn"], cfg, rms_norm(p["ln1"], x), k_cache, v_cache, start)
    x = x + a
    h = rms_norm(p["ln2"], x)
    if cfg.n_experts:
        m, _ = moe_block(p["moe"], cfg, h)
    else:
        m = mlp(p["mlp"], h, cfg.act)
    return x + m, k_cache, v_cache


class TransformerLM:
    """Dense / MoE decoder-only LM."""

    def __init__(self, cfg: ModelConfig, impl: str = "ref") -> None:
        self.cfg = cfg
        self.impl = impl
        # residual-stream sharding constraint (sequence parallelism); set by
        # the launcher: lambda x: with_sharding_constraint(x, P(dp,'model',None))
        self.constraint = lambda x: x

    # ---- params ----------------------------------------------------------
    def init_params(self, key: jax.Array) -> Params:
        cfg = self.cfg
        dtype = dtype_of(cfg)
        k_emb, k_layers, k_fn = jax.random.split(key, 3)
        layer_keys = jax.random.split(k_layers, cfg.n_layers)
        layers = jax.vmap(lambda k: init_block(k, cfg, dtype))(layer_keys)
        return {
            "emb": init_embedding(k_emb, cfg.vocab_padded, cfg.d_model,
                                  dtype, cfg.tie_embeddings),
            "layers": layers,
            "final_norm": init_rms_norm(cfg.d_model, dtype),
        }

    # ---- forward / loss -------------------------------------------------
    def hidden_states(self, params: Params, tokens: jax.Array,
                      mode: str = "train") -> Tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        x = embed(params["emb"], tokens, cfg.embed_scale)
        positions = jnp.arange(tokens.shape[1])[None, :]

        def scan_fn(carry, lp):
            y, aux = block_forward(lp, cfg, carry, positions, self.impl)
            return self.constraint(y), aux

        if cfg.remat and mode == "train":
            scan_fn = jax.checkpoint(scan_fn)
        x, auxs = jax.lax.scan(scan_fn, self.constraint(x), params["layers"])
        x = rms_norm(params["final_norm"], x)
        return x, jnp.mean(auxs)

    def loss(self, params: Params, batch: Dict[str, jax.Array]
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        x, aux = self.hidden_states(params, batch["tokens"], mode="train")
        ce = cross_entropy_loss(params["emb"], x, batch["labels"],
                                cfg.loss_chunk, vocab_valid=cfg.vocab_size)
        total = ce + (MOE_AUX_COEF * aux if cfg.n_experts else 0.0)
        return total, {"ce": ce, "aux": aux}

    # ---- serving ---------------------------------------------------------
    def init_decode_state(self, batch: int, max_seq: int) -> Params:
        return init_kv_cache(self.cfg, batch, max_seq, dtype_of(self.cfg))

    def prefill(self, params: Params, tokens: jax.Array, max_seq: int
                ) -> Tuple[Params, jax.Array]:
        """Run the prompt, building the KV cache; returns (state, last logits)."""
        cfg = self.cfg
        x = embed(params["emb"], tokens, cfg.embed_scale)
        return self.prefill_embeds(params, x, max_seq)

    def prefill_embeds(self, params: Params, x: jax.Array, max_seq: int
                       ) -> Tuple[Params, jax.Array]:
        """Prefill from precomputed embeddings (used by the VLM frontend)."""
        cfg = self.cfg
        B, S = x.shape[:2]
        positions = jnp.arange(S)[None, :]

        def scan_fn(carry, lp):
            h = rms_norm(lp["ln1"], carry)
            # recompute K/V for the cache (same path as attention())
            k = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wk"])
            v = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wv"])
            if cfg.qk_norm:
                from .layers import rms_norm as _rn
                k = _rn(lp["attn"]["k_norm"], k)
            if cfg.pos_embed == "rope":
                from .layers import rotary, apply_rope
                sin, cos = rotary(positions, cfg.hd, cfg.rope_theta)
                k = apply_rope(k, sin, cos)
            y, _ = block_forward(lp, cfg, carry, positions, self.impl)
            return y, (k, v)

        x, (ks, vs) = jax.lax.scan(scan_fn, x, params["layers"])
        x = rms_norm(params["final_norm"], x)
        logits = unembed(params["emb"], x[:, -1:, :])
        state = self.init_decode_state(B, max_seq)
        state["k"] = jax.lax.dynamic_update_slice(
            state["k"], ks.astype(state["k"].dtype), (0, 0, 0, 0, 0))
        state["v"] = jax.lax.dynamic_update_slice(
            state["v"], vs.astype(state["v"].dtype), (0, 0, 0, 0, 0))
        state["length"] = jnp.asarray(S, jnp.int32)
        return state, logits

    def prefill_extend(self, params: Params, state: Params, tokens: jax.Array
                       ) -> Tuple[Params, jax.Array]:
        """Extend a decode state by one prompt chunk (chunked prefill).

        tokens: [B, C] prompt positions state["length"]..length+C-1.  Returns
        (new state, logits at the chunk's last position).  Chaining chunks is
        bit-identical to a single whole-prompt ``prefill`` (future cache
        positions are zero and masked to exactly-zero attention weight).
        """
        cfg = self.cfg
        x = embed(params["emb"], tokens, cfg.embed_scale)
        start = state["length"]

        def scan_fn(carry, inp):
            lp, kc, vc = inp
            y, kc, vc = block_extend(lp, cfg, carry, kc, vc, start)
            return y, (kc, vc)

        x, (new_k, new_v) = jax.lax.scan(
            scan_fn, x, (params["layers"], state["k"], state["v"]))
        x = rms_norm(params["final_norm"], x)
        logits = unembed(params["emb"], x[:, -1:, :])
        new_state = {"k": new_k, "v": new_v,
                     "length": start + jnp.asarray(tokens.shape[1], jnp.int32)}
        return new_state, logits

    def decode_step(self, params: Params, state: Params, tokens: jax.Array
                    ) -> Tuple[Params, jax.Array]:
        """One token for every sequence. tokens: [B, 1]."""
        cfg = self.cfg
        x = embed(params["emb"], tokens, cfg.embed_scale)
        length = state["length"]

        def scan_fn(carry, inp):
            lp, kc, vc = inp
            y, kc, vc = block_decode(lp, cfg, carry, kc, vc, length)
            return y, (kc, vc)

        x, (new_k, new_v) = jax.lax.scan(
            scan_fn, x, (params["layers"], state["k"], state["v"]))
        x = rms_norm(params["final_norm"], x)
        logits = unembed(params["emb"], x)
        new_state = {"k": new_k, "v": new_v, "length": length + 1}
        return new_state, logits
