"""Attention: GQA/MQA with rotary, chunked-causal (flash-style) prefill/train
path and KV-cache decode path.

The chunked causal path is the pure-jnp oracle of the Pallas flash kernel
(``repro.kernels.flash_attention``); which implementation runs is selected by
``impl`` ("ref" on CPU/dry-run, "pallas" on real TPU).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import Params, apply_rope, init_rms_norm, rms_norm, rotary

__all__ = ["init_attention", "attention", "decode_attention", "init_kv_cache",
           "chunked_causal_attention", "dense_causal_attention",
           "extend_attention", "gather_block_table", "scatter_block_rows"]


def init_attention(key: jax.Array, cfg: ModelConfig, dtype) -> Params:
    d, h, hk, hd = cfg.d_model, cfg.n_heads_padded, cfg.n_kv_heads, cfg.hd
    kq, kk, kv, ko = jax.random.split(key, 4)
    s = d ** -0.5
    p = {
        "wq": (jax.random.normal(kq, (d, h, hd), jnp.float32) * s).astype(dtype),
        "wk": (jax.random.normal(kk, (d, hk, hd), jnp.float32) * s).astype(dtype),
        "wv": (jax.random.normal(kv, (d, hk, hd), jnp.float32) * s).astype(dtype),
        "wo": (jax.random.normal(ko, (h, hd, d), jnp.float32)
               * ((h * hd) ** -0.5)).astype(dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rms_norm(hd, dtype)
        p["k_norm"] = init_rms_norm(hd, dtype)
    return p


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return k
    B, S, Hk, hd = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (B, S, Hk, n_rep, hd))
    return k.reshape(B, S, Hk * n_rep, hd)


def dense_causal_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                           causal: bool = True) -> jax.Array:
    """Reference O(S^2)-memory attention. q,k,v: [B, S, H, hd]."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    scale = hd ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        scores = jnp.where(mask[None, None], scores, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def chunked_causal_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                             chunk: int = 1024, causal: bool = True
                             ) -> jax.Array:
    """Flash-style streaming softmax over KV chunks: O(S·chunk) memory.

    This is the jnp oracle for the Pallas kernel.  q,k,v: [B, S, H, hd].
    """
    B, S, H, hd = q.shape
    if S % chunk or S <= chunk:
        return dense_causal_attention(q, k, v, causal)
    n = S // chunk
    scale = hd ** -0.5
    qc = jnp.moveaxis(q.reshape(B, n, chunk, H, hd), 1, 0)
    kc = jnp.moveaxis(k.reshape(B, n, chunk, H, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, n, chunk, H, hd), 1, 0)

    neg = jnp.finfo(jnp.float32).min

    def process_q_chunk(qi_idx_and_q):
        qi, q_i = qi_idx_and_q
        # running accumulators over kv chunks
        acc0 = jnp.zeros((B, chunk, H, hd), jnp.float32)
        m0 = jnp.full((B, chunk, H), neg, jnp.float32)
        l0 = jnp.zeros((B, chunk, H), jnp.float32)

        def kv_body(carry, kj_and_kv):
            acc, m, l = carry
            kj, k_j, v_j = kj_and_kv
            s = jnp.einsum("bqhd,bkhd->bqhk", q_i, k_j).astype(jnp.float32) * scale
            if causal:
                q_pos = qi * chunk + jnp.arange(chunk)
                k_pos = kj * chunk + jnp.arange(chunk)
                mask = q_pos[:, None] >= k_pos[None, :]
                s = jnp.where(mask[None, :, None, :], s, neg)
                # chunks fully in the future contribute nothing
                s = jnp.where(kj <= qi, s, neg)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bqhk,bkhd->bqhd", p.astype(v_j.dtype), v_j).astype(jnp.float32)
            return (acc_new, m_new, l_new), ()

        (acc, m, l), _ = jax.lax.scan(
            kv_body, (acc0, m0, l0), (jnp.arange(n), kc, vc))
        return (acc / jnp.maximum(l[..., None], 1e-30)).astype(q.dtype)

    out = jax.lax.map(process_q_chunk, (jnp.arange(n), qc))
    return jnp.moveaxis(out, 0, 1).reshape(B, S, H, hd)


def triangle_chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                               chunk: int = 1024) -> jax.Array:
    """Causal chunked attention touching ONLY the n(n+1)/2 causal pairs.

    The plain chunked path (above) runs all n² (q-chunk, kv-chunk) pairs and
    masks the future half — 2× wasted MXU work and 2× wasted chunk-buffer
    traffic.  Folding row r with row n-1-r gives every folded row a uniform
    kv trip count of n+1, so a rectangular scan covers exactly the causal
    triangle: FLOPs and interior HBM traffic drop ~2× with bit-identical
    results.  (A beyond-paper optimization; see EXPERIMENTS.md §Perf.)
    """
    B, S, H, hd = q.shape
    n = S // chunk
    if n * chunk != S or n < 2 or n % 2:
        return chunked_causal_attention(q, k, v, chunk)
    scale = hd ** -0.5
    neg = jnp.finfo(jnp.float32).min
    qc = jnp.moveaxis(q.reshape(B, n, chunk, H, hd), 1, 0)
    kc = jnp.moveaxis(k.reshape(B, n, chunk, H, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, n, chunk, H, hd), 1, 0)

    def row_fn(r):
        # folded pair: q chunk r ("lo", needs kv 0..r) and q chunk n-1-r
        # ("hi", needs kv 0..n-1-r); together exactly n+1 kv steps.
        q_lo = qc[r]
        q_hi = qc[n - 1 - r]
        hi_idx = n - 1 - r

        def body(carry, t):
            acc_lo, m_lo, l_lo, acc_hi, m_hi, l_hi = carry
            serve_lo = t <= r
            kv_idx = jnp.where(serve_lo, t, t - (r + 1))
            k_t = jax.lax.dynamic_index_in_dim(kc, kv_idx, 0, keepdims=False)
            v_t = jax.lax.dynamic_index_in_dim(vc, kv_idx, 0, keepdims=False)
            q_sel = jnp.where(serve_lo, q_lo, q_hi)        # elementwise select
            s = jnp.einsum("bqhd,bkhd->bqhk", q_sel,
                           k_t).astype(jnp.float32) * scale
            # mask only the diagonal block of whichever row is served
            q_row = jnp.where(serve_lo, r, hi_idx)
            on_diag = kv_idx == q_row
            q_pos = jnp.arange(chunk)[:, None]
            k_pos = jnp.arange(chunk)[None, :]
            diag_mask = (q_pos >= k_pos) | (~on_diag)
            s = jnp.where(diag_mask[None, :, None, :], s, neg)
            m_prev = jnp.where(serve_lo, m_lo, m_hi)
            l_prev = jnp.where(serve_lo, l_lo, l_hi)
            acc_prev = jnp.where(serve_lo, acc_lo, acc_hi)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
            p_ = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m_prev - m_new)
            l_new = l_prev * alpha + jnp.sum(p_, axis=-1)
            acc_new = acc_prev * alpha[..., None] + jnp.einsum(
                "bqhk,bkhd->bqhd", p_.astype(v_t.dtype),
                v_t).astype(jnp.float32)
            acc_lo = jnp.where(serve_lo, acc_new, acc_lo)
            m_lo = jnp.where(serve_lo, m_new, m_lo)
            l_lo = jnp.where(serve_lo, l_new, l_lo)
            acc_hi = jnp.where(serve_lo, acc_hi, acc_new)
            m_hi = jnp.where(serve_lo, m_hi, m_new)
            l_hi = jnp.where(serve_lo, l_hi, l_new)
            return (acc_lo, m_lo, l_lo, acc_hi, m_hi, l_hi), ()

        z = jnp.zeros((B, chunk, H, hd), jnp.float32)
        m0 = jnp.full((B, chunk, H), neg, jnp.float32)
        l0 = jnp.zeros((B, chunk, H), jnp.float32)
        (acc_lo, m_lo, l_lo, acc_hi, m_hi, l_hi), _ = jax.lax.scan(
            body, (z, m0, l0, z, m0, l0), jnp.arange(n + 1))
        out_lo = (acc_lo / jnp.maximum(l_lo[..., None], 1e-30)).astype(q.dtype)
        out_hi = (acc_hi / jnp.maximum(l_hi[..., None], 1e-30)).astype(q.dtype)
        return out_lo, out_hi

    lo, hi = jax.lax.map(row_fn, jnp.arange(n // 2))
    # lo rows are q chunks 0..n/2-1; hi rows are q chunks n-1..n/2
    out = jnp.concatenate([lo, hi[::-1]], axis=0)          # [n, B, c, H, hd]
    return jnp.moveaxis(out, 0, 1).reshape(B, S, H, hd)


def attention(p: Params, cfg: ModelConfig, x: jax.Array,
              positions: Optional[jax.Array] = None, causal: bool = True,
              impl: str = "ref",
              kv_override: Optional[Tuple[jax.Array, jax.Array]] = None
              ) -> jax.Array:
    """Full-sequence attention (train / prefill). x: [B, S, D] -> [B, S, D].

    ``kv_override`` supplies externally computed K/V (cross-attention).
    """
    B, S, D = x.shape
    h, hk, hd = cfg.n_heads_padded, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if kv_override is None:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    else:
        k, v = kv_override
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q)
        k = rms_norm(p["k_norm"], k) if kv_override is None else k
    if cfg.pos_embed == "rope":
        if positions is None:
            positions = jnp.arange(S)[None, :]
        sin, cos = rotary(positions, hd, cfg.rope_theta)
        q = apply_rope(q, sin, cos)
        if kv_override is None:
            k = apply_rope(k, sin, cos)
    n_rep = h // k.shape[2]
    k = _repeat_kv(k, n_rep)
    v = _repeat_kv(v, n_rep)
    if impl == "pallas":
        from ..kernels.flash_attention.ops import flash_attention
        out = flash_attention(q, k, v, causal=causal)
    elif causal and cfg.attn_chunk and S > cfg.attn_chunk:
        if cfg.attn_tri:
            out = triangle_chunked_attention(q, k, v, cfg.attn_chunk)
        else:
            out = chunked_causal_attention(q, k, v, cfg.attn_chunk, causal)
    else:
        out = dense_causal_attention(q, k, v, causal)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# --------------------------------------------------------------------------
# decode path
# --------------------------------------------------------------------------
def init_kv_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype,
                  n_layers: Optional[int] = None) -> Dict[str, jax.Array]:
    """KV cache [L, B, S, Hkv, hd] + current length."""
    L = n_layers if n_layers is not None else cfg.n_layers
    shape = (L, batch, max_seq, cfg.n_kv_heads, cfg.hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "length": jnp.zeros((), jnp.int32),
    }


def decode_attention(p: Params, cfg: ModelConfig, x: jax.Array,
                     k_cache: jax.Array, v_cache: jax.Array,
                     length: jax.Array,
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One-token attention against a cache.

    x: [B, 1, D]; k_cache/v_cache: [B, S_max, Hkv, hd]; length: [] int32 —
    number of valid cache positions (the new token is written at ``length``).
    Returns (out [B,1,D], new_k_cache, new_v_cache).
    """
    B, _, D = x.shape
    h, hk, hd = cfg.n_heads_padded, cfg.n_kv_heads, cfg.hd
    S = k_cache.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q)
        k_new = rms_norm(p["k_norm"], k_new)
    if cfg.pos_embed == "rope":
        pos = length[None, None]
        sin, cos = rotary(pos, hd, cfg.rope_theta)
        q = apply_rope(q, sin, cos)
        k_new = apply_rope(k_new, sin, cos)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k_new.astype(k_cache.dtype), (0, length, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v_new.astype(v_cache.dtype), (0, length, 0, 0))
    n_rep = h // hk
    scale = hd ** -0.5
    # scores against the whole cache; invalid positions masked by length
    q_ = q.reshape(B, hk, n_rep, hd)
    scores = jnp.einsum("bgrd,bsgd->bgrs", q_, k_cache).astype(jnp.float32) * scale
    valid = jnp.arange(S)[None, None, None, :] <= length
    scores = jnp.where(valid, scores, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgrs,bsgd->bgrd", probs.astype(v_cache.dtype), v_cache)
    out = out.reshape(B, 1, h, hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), k_cache, v_cache


def extend_attention(p: Params, cfg: ModelConfig, x: jax.Array,
                     k_cache: jax.Array, v_cache: jax.Array,
                     start: jax.Array,
                     ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Prompt-chunk attention against a cache (chunked prefill).

    x: [B, C, D] — C new prompt positions starting at global position
    ``start`` ([] int32); k_cache/v_cache: [B, S_max, Hkv, hd] holding the
    first ``start`` positions.  The chunk's K/V are written at
    [start, start+C) and the chunk queries attend causally over the whole
    buffer (future positions hold zeros and are masked to exactly-zero
    softmax weight, so results are bit-identical to whole-prompt prefill).
    Returns (out [B,C,D], new_k_cache, new_v_cache).
    """
    B, C, D = x.shape
    h, hk, hd = cfg.n_heads_padded, cfg.n_kv_heads, cfg.hd
    S = k_cache.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(p["q_norm"], q)
        k_new = rms_norm(p["k_norm"], k_new)
    if cfg.pos_embed == "rope":
        pos = start + jnp.arange(C)[None, :]
        sin, cos = rotary(pos, hd, cfg.rope_theta)
        q = apply_rope(q, sin, cos)
        k_new = apply_rope(k_new, sin, cos)
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k_new.astype(k_cache.dtype), (0, start, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v_new.astype(v_cache.dtype), (0, start, 0, 0))
    n_rep = h // hk
    k_r = _repeat_kv(k_cache, n_rep)
    v_r = _repeat_kv(v_cache, n_rep)
    scale = hd ** -0.5
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k_r).astype(jnp.float32) * scale
    q_pos = start + jnp.arange(C)
    mask = jnp.arange(S)[None, :] <= q_pos[:, None]
    scores = jnp.where(mask[None, None], scores, jnp.finfo(jnp.float32).min)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v_r)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), k_cache, v_cache


# --------------------------------------------------------------------------
# paged KV: block-table gather / scatter
# --------------------------------------------------------------------------
def gather_block_table(pool: jax.Array, table: jax.Array) -> jax.Array:
    """Gather one slot's pages into a contiguous batch-1 cache.

    pool: [L, P, page_tokens, Hkv, hd]; table: [n_blk] int32 physical page
    ids.  Returns [L, 1, n_blk*page_tokens, Hkv, hd] — the same layout the
    dense decode path uses, so the decode math downstream is shared (and
    bit-identical) between backends.
    """
    L, P, pt, Hk, hd = pool.shape
    g = pool[:, table]  # [L, n_blk, pt, Hk, hd]
    return g.reshape(L, 1, table.shape[0] * pt, Hk, hd)


def scatter_block_rows(pool: jax.Array, table: jax.Array, rows: jax.Array,
                       start: jax.Array) -> jax.Array:
    """Write ``rows`` [L, n, Hkv, hd] at logical positions start..start+n-1.

    Positions are clamped exactly the way ``dynamic_update_slice`` clamps the
    dense cache write (overshoot past max_seq lands in the final page), so a
    request finishing at the KV cap behaves identically to dense.
    """
    L, P, pt, Hk, hd = pool.shape
    n_blk = table.shape[0]
    n = rows.shape[1]
    S = n_blk * pt

    def body(t, pool):
        pos = jnp.minimum(start + t, S - 1)
        page = table[jnp.minimum(pos // pt, n_blk - 1)]
        off = pos % pt
        row = jax.lax.dynamic_slice(rows, (0, t, 0, 0), (L, 1, Hk, hd))
        return jax.lax.dynamic_update_slice(
            pool, row[:, None].astype(pool.dtype), (0, page, off, 0, 0))

    return jax.lax.fori_loop(0, n, body, pool)
