"""Mamba2 (SSD — state-space duality) block: chunked scan formulation.

The chunked algorithm (Dao & Gu, 2024) splits the sequence into chunks of Q:
within a chunk the output is an attention-like quadratic term masked by the
cumulative decay; across chunks a small recurrent state [H, hd, N] is carried.
This maps naturally onto the TPU: the intra-chunk term is MXU-friendly
matmuls, the inter-chunk scan is O(S/Q) sequential steps.  The pure-jnp
implementation here is the oracle for ``repro.kernels.ssd_scan``.

Projections are kept *separate* (z/x/B/C/dt) rather than fused, so each is
cleanly tensor-parallel: the x-path (heads) shards over the model axis while
the small shared B/C paths replicate — fused layouts would slice across
shard boundaries and force resharding collectives.

Decode carries state [B, H, hd, N] and conv ring buffers — O(1) per token
(this is why the ssm/hybrid archs run the ``long_500k`` shape).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import Params, init_rms_norm, rms_norm

__all__ = ["init_mamba", "mamba_block", "mamba_decode_step", "init_ssm_state",
           "ssd_chunked"]


def init_mamba(key: jax.Array, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    di = cfg.d_inner
    ns = cfg.ssm_state
    nh = cfg.ssm_heads
    K = cfg.ssm_conv
    ks = jax.random.split(key, 8)
    s = d ** -0.5
    return {
        "z_proj": (jax.random.normal(ks[0], (d, di), jnp.float32) * s).astype(dtype),
        "x_proj": (jax.random.normal(ks[1], (d, di), jnp.float32) * s).astype(dtype),
        "B_proj": (jax.random.normal(ks[2], (d, ns), jnp.float32) * s).astype(dtype),
        "C_proj": (jax.random.normal(ks[3], (d, ns), jnp.float32) * s).astype(dtype),
        "dt_proj": (jax.random.normal(ks[4], (d, nh), jnp.float32) * s).astype(dtype),
        "conv_x_w": (jax.random.normal(ks[5], (K, di), jnp.float32) * 0.1).astype(dtype),
        "conv_x_b": jnp.zeros((di,), dtype),
        "conv_B_w": (jax.random.normal(ks[6], (K, ns), jnp.float32) * 0.1).astype(dtype),
        "conv_B_b": jnp.zeros((ns,), dtype),
        "conv_C_w": (jax.random.normal(ks[7], (K, ns), jnp.float32) * 0.1).astype(dtype),
        "conv_C_b": jnp.zeros((ns,), dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),            # A = -exp(A_log)
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": init_rms_norm(di, dtype),
        "out_proj": (jax.random.normal(ks[0], (di, d), jnp.float32)
                     * (di ** -0.5)).astype(dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv via shifted adds. x: [B,S,C]; w: [K,C]."""
    K = w.shape[0]
    out = x * w[K - 1]
    for i in range(1, K):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * w[K - 1 - i]
    return jax.nn.silu(out + b)


def ssd_chunked(xh: jax.Array, dt: jax.Array, A: jax.Array, Bc: jax.Array,
                Cc: jax.Array, chunk: int,
                h0: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    xh: [B, S, H, P] head inputs; dt: [B, S, H] (post-softplus);
    A: [H] (negative); Bc/Cc: [B, S, N] (single group).
    Returns (y [B,S,H,P], final state [B,H,P,N]).
    """
    B, S, H, P = xh.shape
    N = Bc.shape[-1]
    n = S // chunk
    assert n * chunk == S, "sequence must be divisible by ssm chunk"

    xc = jnp.moveaxis(xh.reshape(B, n, chunk, H, P), 1, 0)
    dtc = jnp.moveaxis(dt.reshape(B, n, chunk, H), 1, 0)
    Bcc = jnp.moveaxis(Bc.reshape(B, n, chunk, N), 1, 0)
    Ccc = jnp.moveaxis(Cc.reshape(B, n, chunk, N), 1, 0)

    dA = dtc * A[None, None, None, :]                      # [n,B,Q,H] (<=0)
    cum = jnp.cumsum(dA, axis=2)                           # within-chunk cumsum
    seg_total = cum[:, :, -1, :]                           # [n,B,H]

    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    def body(h, args):
        x_i, dt_i, B_i, C_i, cum_i, tot_i = args
        # ---- intra-chunk (quadratic, attention-like) ----
        # L[q,k] = exp(cum[q]-cum[k]) for q>=k
        diff = cum_i[:, :, None, :] - cum_i[:, None, :, :]          # [B,Q,Q,H]
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        L = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
        CB = jnp.einsum("bqn,bkn->bqk", C_i, B_i).astype(jnp.float32)
        G = CB[..., None] * L                                       # [B,Q,Q,H]
        y_intra = jnp.einsum("bqkh,bkh,bkhp->bqhp", G, dt_i, x_i)
        # ---- inter-chunk (read carried state) ----
        decay_q = jnp.exp(cum_i)                                    # [B,Q,H]
        y_inter = jnp.einsum("bqn,bhpn,bqh->bqhp",
                             C_i.astype(jnp.float32), h, decay_q)
        # ---- state update ----
        decay_suf = jnp.exp(tot_i[:, None, :] - cum_i)              # [B,Q,H]
        dB = jnp.einsum("bqh,bqn->bqhn", dt_i * decay_suf, B_i)
        h_new = h * jnp.exp(tot_i)[:, :, None, None] + jnp.einsum(
            "bqhn,bqhp->bhpn", dB, x_i.astype(jnp.float32))
        return h_new, (y_intra + y_inter)

    h_final, yc = jax.lax.scan(
        body, h0, (xc.astype(jnp.float32), dtc, Bcc.astype(jnp.float32),
                   Ccc.astype(jnp.float32), cum, seg_total))
    y = jnp.moveaxis(yc, 0, 1).reshape(B, S, H, P)
    return y.astype(xh.dtype), h_final


def mamba_block(p: Params, cfg: ModelConfig, x: jax.Array,
                impl: str = "ref") -> jax.Array:
    """Full-sequence Mamba2 block. x: [B, S, D] -> [B, S, D]."""
    B, S, D = x.shape
    di, ns, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z = x @ p["z_proj"]
    xs = _causal_conv(x @ p["x_proj"], p["conv_x_w"], p["conv_x_b"])
    Bc = _causal_conv(x @ p["B_proj"], p["conv_B_w"], p["conv_B_b"])
    Cc = _causal_conv(x @ p["C_proj"], p["conv_C_w"], p["conv_C_b"])
    dt = jax.nn.softplus((x @ p["dt_proj"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(B, S, nh, hd)
    chunk = min(cfg.ssm_chunk, S)
    if impl == "pallas":
        from ..kernels.ssd_scan.ops import ssd_scan
        y, _ = ssd_scan(xh, dt, A, Bc, Cc, chunk=chunk)
    else:
        y, _ = ssd_chunked(xh, dt, A, Bc, Cc, chunk=chunk)
    y = y + xh * p["D"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = rms_norm(p["norm"], y * jax.nn.silu(z))
    return y @ p["out_proj"]


# --------------------------------------------------------------------------
# decode path
# --------------------------------------------------------------------------
def init_ssm_state(cfg: ModelConfig, batch: int, dtype,
                   n_layers: Optional[int] = None) -> Dict[str, jax.Array]:
    L = n_layers if n_layers is not None else cfg.n_layers
    nh, hd, ns = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    K = cfg.ssm_conv
    return {
        "h": jnp.zeros((L, batch, nh, hd, ns), jnp.float32),
        "conv_x": jnp.zeros((L, batch, K - 1, cfg.d_inner), dtype),
        "conv_B": jnp.zeros((L, batch, K - 1, ns), dtype),
        "conv_C": jnp.zeros((L, batch, K - 1, ns), dtype),
    }


def _conv_step(window_prev: jax.Array, new: jax.Array, w: jax.Array,
               b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One causal-conv step. window_prev: [B,K-1,C]; new: [B,C]."""
    window = jnp.concatenate([window_prev, new[:, None, :]], axis=1)  # [B,K,C]
    out = jnp.einsum("bkc,kc->bc", window, w) + b
    return jax.nn.silu(out), window[:, 1:]


def mamba_decode_step(p: Params, cfg: ModelConfig, x: jax.Array,
                      state: Dict[str, jax.Array]
                      ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """One-token Mamba2 step.

    x: [B, 1, D]; state: {h [B,H,P,N], conv_x [B,K-1,di], conv_B, conv_C}.
    Returns (y [B,1,D], new_state).
    """
    B = x.shape[0]
    di, ns, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    xt = x[:, 0]                                            # [B, D]
    z = xt @ p["z_proj"]
    xs, conv_x = _conv_step(state["conv_x"], xt @ p["x_proj"],
                            p["conv_x_w"], p["conv_x_b"])
    Bc, conv_B = _conv_step(state["conv_B"], xt @ p["B_proj"],
                            p["conv_B_w"], p["conv_B_b"])
    Cc, conv_C = _conv_step(state["conv_C"], xt @ p["C_proj"],
                            p["conv_C_w"], p["conv_C_b"])
    dt = jax.nn.softplus((xt @ p["dt_proj"]).astype(jnp.float32)
                         + p["dt_bias"])                    # [B,H]
    A = -jnp.exp(p["A_log"])                                # [H]
    xh = xs.reshape(B, nh, hd).astype(jnp.float32)
    dA = jnp.exp(dt * A[None, :])                           # [B,H]
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt, Bc.astype(jnp.float32), xh)
    h_new = state["h"] * dA[..., None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", Cc.astype(jnp.float32), h_new)
    y = y + xh * p["D"][None, :, None]
    y = y.reshape(B, di).astype(x.dtype)
    y = rms_norm(p["norm"], y * jax.nn.silu(z))
    new_state = {"h": h_new, "conv_x": conv_x, "conv_B": conv_B,
                 "conv_C": conv_C}
    return (y @ p["out_proj"])[:, None, :], new_state
