"""LLaVA-style VLM backbone.

The vision tower is a STUB per the assignment: ``input_specs`` supplies
precomputed anyres patch embeddings [B, n_patches, d_model] (what the CLIP
tower + projector would emit).  The backbone is a dense decoder-only LM;
patch embeddings are prepended to the text embeddings, the loss covers text
positions only.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import (Params, cross_entropy_loss, dtype_of, embed, rms_norm,
                     unembed)
from .transformer import TransformerLM

__all__ = ["VlmLM"]


class VlmLM(TransformerLM):
    """TransformerLM with injected patch embeddings."""

    def _inject(self, params: Params, tokens: jax.Array,
                patches: jax.Array) -> jax.Array:
        text = embed(params["emb"], tokens, self.cfg.embed_scale)
        return jnp.concatenate([patches.astype(text.dtype), text], axis=1)

    def _forward_embeds(self, params: Params, x: jax.Array, mode: str
                        ) -> jax.Array:
        cfg = self.cfg
        positions = jnp.arange(x.shape[1])[None, :]
        from .transformer import block_forward

        def scan_fn(carry, lp):
            y, aux = block_forward(lp, cfg, carry, positions, self.impl)
            return self.constraint(y), aux

        if cfg.remat and mode == "train":
            scan_fn = jax.checkpoint(scan_fn)
        x, _ = jax.lax.scan(scan_fn, self.constraint(x), params["layers"])
        return rms_norm(params["final_norm"], x)

    def loss(self, params: Params, batch: Dict[str, jax.Array]
             ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        cfg = self.cfg
        x = self._inject(params, batch["tokens"], batch["patch_embeds"])
        x = self._forward_embeds(params, x, mode="train")
        n_p = batch["patch_embeds"].shape[1]
        ce = cross_entropy_loss(params["emb"], x[:, n_p:], batch["labels"],
                                cfg.loss_chunk, vocab_valid=cfg.vocab_size)
        return ce, {"ce": ce}

    def prefill(self, params: Params, tokens: jax.Array, max_seq: int,
                patch_embeds: jax.Array = None) -> Tuple[Params, jax.Array]:
        if patch_embeds is None:
            return super().prefill(params, tokens, max_seq)
        x = self._inject(params, tokens, patch_embeds)
        # full prefill incl. KV-cache assembly (shared with the text path)
        return self.prefill_embeds(params, x, max_seq)
