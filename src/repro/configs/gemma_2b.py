"""Gemma 2B — dense, GeGLU, MQA (kv=1), head_dim 256.

[arXiv:2403.08295; hf] 18L, d_model 2048, 8H, d_ff 16384, vocab 256000.
Tied embeddings with sqrt(d_model) input scaling.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_ff=16384,
    vocab_size=256000, head_dim=256,
    act="gelu", tie_embeddings=True, embed_scale=True,
)

SMOKE = ModelConfig(
    name="gemma-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
    vocab_size=256, head_dim=32,
    act="gelu", tie_embeddings=True, embed_scale=True,
    remat=False, attn_chunk=0, loss_chunk=64,
)
