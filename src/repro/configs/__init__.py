"""Assigned architecture configs (exact published values) + smoke variants."""
from __future__ import annotations

from typing import Dict

from .base import ModelConfig, resolve
from .shapes import SHAPES, ShapeConfig, applicable, skip_reason

from .jamba_v01_52b import CONFIG as jamba_v01_52b, SMOKE as jamba_smoke
from .grok_1_314b import CONFIG as grok_1_314b, SMOKE as grok_smoke
from .qwen2_moe_a2_7b import CONFIG as qwen2_moe_a2_7b, SMOKE as qwen2_moe_smoke
from .gemma_2b import CONFIG as gemma_2b, SMOKE as gemma_smoke
from .deepseek_7b import CONFIG as deepseek_7b, SMOKE as deepseek_smoke
from .llama3_405b import CONFIG as llama3_405b, SMOKE as llama3_smoke
from .qwen3_8b import CONFIG as qwen3_8b, SMOKE as qwen3_smoke
from .whisper_medium import CONFIG as whisper_medium, SMOKE as whisper_smoke
from .mamba2_780m import CONFIG as mamba2_780m, SMOKE as mamba2_smoke
from .llava_next_34b import CONFIG as llava_next_34b, SMOKE as llava_smoke

ARCHS: Dict[str, ModelConfig] = {
    "jamba-v0.1-52b": jamba_v01_52b,
    "grok-1-314b": grok_1_314b,
    "qwen2-moe-a2.7b": qwen2_moe_a2_7b,
    "gemma-2b": gemma_2b,
    "deepseek-7b": deepseek_7b,
    "llama3-405b": llama3_405b,
    "qwen3-8b": qwen3_8b,
    "whisper-medium": whisper_medium,
    "mamba2-780m": mamba2_780m,
    "llava-next-34b": llava_next_34b,
}

SMOKE_ARCHS: Dict[str, ModelConfig] = {
    "jamba-v0.1-52b": jamba_smoke,
    "grok-1-314b": grok_smoke,
    "qwen2-moe-a2.7b": qwen2_moe_smoke,
    "gemma-2b": gemma_smoke,
    "deepseek-7b": deepseek_smoke,
    "llama3-405b": llama3_smoke,
    "qwen3-8b": qwen3_smoke,
    "whisper-medium": whisper_smoke,
    "mamba2-780m": mamba2_smoke,
    "llava-next-34b": llava_smoke,
}

__all__ = ["ARCHS", "SMOKE_ARCHS", "SHAPES", "ModelConfig", "ShapeConfig",
           "applicable", "skip_reason", "resolve"]
