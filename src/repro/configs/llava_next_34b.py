"""LLaVA-NeXT 34B — VLM; vision tower STUBBED (anyres patch embeddings).

[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified] Backbone (Yi-34B-ish):
60L, d_model 7168, 56H (kv=8), d_ff 20480, vocab 64000.  ``input_specs``
supplies 576 precomputed patch embeddings.  56 heads are padded to 64 for
the 16-way tensor-parallel axis (adaptation in DESIGN.md).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=20480,
    vocab_size=64000, head_dim=128, act="silu", rope_theta=5000000.0,
    n_patches=576,
    fsdp=True,
)

SMOKE = ModelConfig(
    name="llava-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, head_dim=16, act="silu",
    n_patches=8,
    remat=False, attn_chunk=0, loss_chunk=64,
)
