"""Assigned input shapes (LM-family: seq_len × global_batch)."""
from __future__ import annotations

import dataclasses
from typing import Dict

from .base import ModelConfig

__all__ = ["ShapeConfig", "SHAPES", "applicable", "skip_reason"]


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> str:
    """Empty string if the (arch, shape) cell runs; else why it is skipped."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return ("long_500k requires sub-quadratic attention; "
                f"{cfg.name} is a pure full-attention arch (skip per spec)")
    return ""


def applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    return not skip_reason(cfg, shape)
