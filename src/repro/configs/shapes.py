"""Assigned input shapes (LM-family: seq_len × global_batch)."""
from __future__ import annotations

import dataclasses
from typing import Dict

from .base import ModelConfig

__all__ = ["ShapeConfig", "SHAPES", "ServeShape", "SERVE_SHAPES",
           "kv_geometry", "applicable", "skip_reason"]


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ServeShape:
    """Serving-engine geometry: KV slots × sequence budget × page layout.

    ``kv_page_tokens`` is the paged-backend page size (tokens per page);
    ``prefill_chunk`` bounds a single prefill launch (0 = whole-prompt).
    These are the serving analogue of :class:`ShapeConfig` — the loadtest
    CLI and the tuner resolve their defaults from here.
    """

    name: str
    slots: int
    max_seq: int
    kv_page_tokens: int
    prefill_chunk: int = 0

    def geometry(self) -> "tuple[int, int]":
        return kv_geometry(self.max_seq, self.kv_page_tokens, self.slots)


SERVE_SHAPES: Dict[str, ServeShape] = {
    "chat_smoke": ServeShape("chat_smoke", 4, 64, 16, 8),
    "chat_4k": ServeShape("chat_4k", 64, 4096, 64, 512),
    "longform_32k": ServeShape("longform_32k", 16, 32768, 128, 1024),
}


def kv_geometry(max_seq: int, page_tokens: int, slots: int
                ) -> "tuple[int, int]":
    """(blocks per slot, default pool pages) for a paged KV layout.

    The default pool holds every slot fully grown (plus the reserved
    scratch page slot 0 adds on top), so page exhaustion cannot occur
    unless the pool is explicitly shrunk — which keeps the paged backend
    token-identical to dense under any workload at default settings.
    """
    if page_tokens <= 0:
        raise ValueError(f"kv_page_tokens must be positive, got {page_tokens}")
    if max_seq % page_tokens:
        raise ValueError(
            f"max_seq={max_seq} is not a multiple of kv_page_tokens="
            f"{page_tokens}; the block table would need a ragged last page")
    n_blocks = max_seq // page_tokens
    return n_blocks, slots * n_blocks


def skip_reason(cfg: ModelConfig, shape: ShapeConfig) -> str:
    """Empty string if the (arch, shape) cell runs; else why it is skipped."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return ("long_500k requires sub-quadratic attention; "
                f"{cfg.name} is a pure full-attention arch (skip per spec)")
    return ""


def applicable(cfg: ModelConfig, shape: ShapeConfig) -> bool:
    return not skip_reason(cfg, shape)
