"""Jamba v0.1 52B — hybrid Mamba+attention 1:7, MoE 16e top-2.

[arXiv:2403.19887; hf] 32L, d_model 4096, 32H (GQA kv=8), d_ff 14336,
vocab 65536.  Period-8 blocks: attention at in-block index 4, Mamba
elsewhere; MoE replaces the MLP on every 2nd layer.  No explicit positional
encoding (the Mamba layers carry position).  SSM uses the SSD (mamba2)
formulation for the TPU-chunked kernel — adaptation noted in DESIGN.md;
Jamba's published d_state=16 is kept.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=65536,
    n_experts=16, top_k=2, moe_every=2,
    attn_every=8, attn_offset=4,
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
    pos_embed="none", act="silu",
    fsdp=True,
)

SMOKE = ModelConfig(
    name="jamba-smoke", family="hybrid",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256,
    n_experts=4, top_k=2, moe_every=2,
    attn_every=8, attn_offset=4,
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_conv=4, ssm_chunk=16,
    pos_embed="none", act="silu",
    remat=False, attn_chunk=0, loss_chunk=64,
)
