"""DeepSeek LLM 7B — dense llama-arch.

[arXiv:2401.02954; hf] 30L, d_model 4096, 32H (kv=32), d_ff 11008,
vocab 102400.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b", family="dense",
    n_layers=30, d_model=4096, n_heads=32, n_kv_heads=32, d_ff=11008,
    vocab_size=102400, act="silu",
)

SMOKE = ModelConfig(
    name="deepseek-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, act="silu",
    remat=False, attn_chunk=0, loss_chunk=64,
)
