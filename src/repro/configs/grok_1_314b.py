"""Grok-1 314B — MoE 8 experts top-2.

[hf:xai-org/grok-1; unverified] 64L, d_model 6144, 48H (GQA kv=8),
d_ff 32768 (expert FFN), vocab 131072.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=32768,
    vocab_size=131072,
    n_experts=8, top_k=2, act="gelu",
    fsdp=True,
)

SMOKE = ModelConfig(
    name="grok-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256,
    n_experts=4, top_k=2, act="gelu",
    remat=False, attn_chunk=0, loss_chunk=64,
)
