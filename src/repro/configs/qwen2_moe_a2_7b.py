"""Qwen1.5-MoE-A2.7B — 60 routed experts top-4 + 4 shared.

[hf:Qwen/Qwen1.5-MoE-A2.7B; hf] 24L, d_model 2048, 16H (kv=16),
expert d_ff 1408 (shared-expert capacity 4x1408 = 5632), vocab 151936.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b", family="moe",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab_size=151936,
    n_experts=60, top_k=4, n_shared_experts=4, act="silu",
)

SMOKE = ModelConfig(
    name="qwen2-moe-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=32,
    vocab_size=256,
    n_experts=8, top_k=4, n_shared_experts=2, act="silu",
    remat=False, attn_chunk=0, loss_chunk=64,
)
