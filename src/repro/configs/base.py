"""Model/shape configuration system.

Every assigned architecture gets one file in this package with its exact
published configuration; reduced smoke variants derive from the same
dataclass.  Shapes (the assigned input-shape set) live in ``shapes.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "pad_to_multiple", "resolve"]


def pad_to_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 → d_model // n_heads

    # --- MoE ---------------------------------------------------------------
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_every: int = 1           # MoE replaces the MLP every k-th layer
    capacity_factor: float = 1.25
    moe_impl: str = "sorted"     # sorted | dense (reference)

    # --- activation / norm ---------------------------------------------------
    act: str = "silu"            # silu (SwiGLU) | gelu (GeGLU)
    qk_norm: bool = False
    tie_embeddings: bool = False
    embed_scale: bool = False    # gemma-style sqrt(d_model) embed scaling

    # --- SSM (mamba2 / hybrid) ----------------------------------------------
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256

    # --- hybrid (jamba) -------------------------------------------------------
    attn_every: int = 0          # attention layer every k-th (0 = all attn)
    attn_offset: int = 4         # position of the attn layer within the period

    # --- encoder-decoder (whisper) ---------------------------------------------
    is_encoder_decoder: bool = False
    n_enc_layers: int = 0
    enc_seq_ratio: int = 1       # encoder frames per decoder token (shape calc)

    # --- vlm (llava) -----------------------------------------------------------
    n_patches: int = 0           # stub frontend: injected patch embeddings

    # --- positional ------------------------------------------------------------
    pos_embed: str = "rope"      # rope | learned | sinusoidal
    rope_theta: float = 10000.0
    max_position: int = 1 << 20

    # --- numerics / execution ---------------------------------------------------
    param_dtype: str = "bfloat16"
    remat: bool = True           # activation checkpointing for train
    attn_chunk: int = 1024       # KV block size for the chunked causal path
    attn_tri: bool = False       # triangle-folded chunk iteration (~2x less
                                 # attention compute+traffic; see §Perf)
    loss_chunk: int = 512        # sequence chunk for CE loss
    scan_layers: bool = True

    # --- sharding hints -----------------------------------------------------------
    fsdp: bool = False           # shard weights over the data axis too
    seq_shard: bool = False      # sequence-parallel residual stream (SP)
    microbatch: int = 1          # gradient-accumulation microbatches
    pad_heads_to: int = 0        # pad n_heads for TP divisibility (0 = none)
    pad_vocab_to: int = 0        # padded vocab (0 = none)

    # -------------------------------------------------------------------------
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def n_heads_padded(self) -> int:
        return self.pad_heads_to or self.n_heads

    @property
    def vocab_padded(self) -> int:
        return self.pad_vocab_to or self.vocab_size

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def is_moe_layer(self, i: int) -> bool:
        if self.n_experts == 0:
            return False
        if self.moe_every <= 1:
            return True
        return (i % self.moe_every) == 1

    def is_attn_layer(self, i: int) -> bool:
        """hybrid only: which layers are attention (rest are SSM)."""
        if self.family != "hybrid":
            return True
        if self.attn_every <= 0:
            return True
        return (i % self.attn_every) == self.attn_offset

    # --- parameter counts (for MODEL_FLOPS) -------------------------------------
    def _attn_params(self) -> int:
        h, hk, hd, d = self.n_heads_padded, self.n_kv_heads, self.hd, self.d_model
        return d * h * hd + 2 * d * hk * hd + h * hd * d

    def _mlp_params(self, ff: Optional[int] = None) -> int:
        ff = ff or self.d_ff
        return 3 * self.d_model * ff  # gate, up, down

    def _ssm_params(self) -> int:
        d, di, st = self.d_model, self.d_inner, self.ssm_state
        nh = self.ssm_heads
        # in_proj (z, x, B, C, dt), conv, A/D, out_proj, norm
        in_p = d * (2 * di + 2 * st + nh)
        conv = (di + 2 * st) * self.ssm_conv
        out_p = di * d
        return in_p + conv + out_p + 2 * nh + di

    def param_counts(self) -> Tuple[int, int]:
        """(total_params, active_params) excluding embeddings.

        active = params touched per token (MoE: top_k + shared experts).
        """
        total = 0
        active = 0
        layers = range(self.n_layers)
        for i in layers:
            if self.family in ("hybrid",) and not self.is_attn_layer(i):
                total += self._ssm_params()
                active += self._ssm_params()
            elif self.family == "ssm":
                total += self._ssm_params()
                active += self._ssm_params()
            else:
                total += self._attn_params()
                active += self._attn_params()
            if self.family == "ssm":
                continue  # mamba2: no MLP
            if self.is_moe_layer(i):
                total += self.n_experts * self._mlp_params()
                active += self.top_k * self._mlp_params()
                if self.n_shared_experts:
                    total += self.n_shared_experts * self._mlp_params()
                    active += self.n_shared_experts * self._mlp_params()
                total += self.d_model * self.n_experts  # router
                active += self.d_model * self.n_experts
            else:
                total += self._mlp_params()
                active += self._mlp_params()
        if self.is_encoder_decoder:
            # encoder self-attn + mlp, decoder cross-attn
            enc = self.n_enc_layers * (self._attn_params() + self._mlp_params())
            cross = self.n_layers * self._attn_params()
            total += enc + cross
            active += enc + cross
        emb = self.vocab_padded * self.d_model
        total += emb if self.tie_embeddings else 2 * emb
        active += emb if self.tie_embeddings else 2 * emb
        return total, active


def resolve(cfg: ModelConfig, model_axis: int = 16) -> ModelConfig:
    """Apply divisibility padding for a given tensor-parallel axis size."""
    kw = {}
    if cfg.vocab_size % model_axis:
        kw["pad_vocab_to"] = pad_to_multiple(cfg.vocab_size, model_axis)
    if cfg.n_heads % model_axis and cfg.family not in ("ssm",):
        kw["pad_heads_to"] = pad_to_multiple(cfg.n_heads, model_axis)
    if not kw:
        return cfg
    return dataclasses.replace(cfg, **kw)
