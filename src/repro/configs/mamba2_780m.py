"""Mamba2 780M — attention-free SSD (state-space duality).

[arXiv:2405.21060; unverified] 48L, d_model 1536, vocab 50280,
ssm_state 128, expand 2, head_dim 64, conv width 4.  No MLP (d_ff=0).
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
    pos_embed="none", tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=256,
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_conv=4, ssm_chunk=16,
    pos_embed="none", tie_embeddings=True,
    remat=False, attn_chunk=0, loss_chunk=64,
)
