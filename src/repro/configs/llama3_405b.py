"""Llama 3 405B — dense, GQA kv=8, 128k vocab.

[arXiv:2407.21783; unverified] 126L, d_model 16384, 128H (kv=8),
d_ff 53248, vocab 128256, rope theta 500000.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16384, n_heads=128, n_kv_heads=8, d_ff=53248,
    vocab_size=128256, act="silu", rope_theta=500000.0,
    fsdp=True,
)

SMOKE = ModelConfig(
    name="llama3-smoke", family="dense",
    n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, d_ff=128,
    vocab_size=256, act="silu", rope_theta=500000.0,
    remat=False, attn_chunk=0, loss_chunk=64,
)
