"""Whisper medium — encoder-decoder; conv frontend STUBBED.

[arXiv:2212.04356; unverified] 24L enc + 24L dec, d_model 1024, 16H
(kv=16), d_ff 4096, vocab 51865.  ``input_specs`` supplies precomputed
frame embeddings (post-conv).  Decoder uses learned positions extended to
max_position=32768 for the assigned decode shape (adaptation in DESIGN.md);
cross-attention KV is Whisper's fixed 1500-frame encoder output.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab_size=51865,
    is_encoder_decoder=True, n_enc_layers=24, enc_seq_ratio=4,
    pos_embed="learned", max_position=32768, act="gelu",
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="audio",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256,
    is_encoder_decoder=True, n_enc_layers=2, enc_seq_ratio=4,
    pos_embed="learned", max_position=512, act="gelu",
    remat=False, attn_chunk=0, loss_chunk=64,
)
