"""Fleet-wide trace aggregation: many per-process shards, one timeline.

The paper's watchpoint observes *one* GPU's command stream completely; the
ROADMAP's fleet is many hosts, each with its own :class:`TraceSession`
writing a JSONL shard under its own monotonic clock (``perf_counter`` is
process-local and starts at an arbitrary zero).  This module merges those
shards back into one cross-host, submission-ordered timeline — the
fleet-wide analogue of "complete capture at the commit point".

Clock-skew alignment
--------------------
Two mechanisms, best one wins per shard:

1. **Shared barriers** (preferred): every process emits
   ``session.barrier("id")`` at the same real moment (after a collective, at
   mesh setup).  For each non-reference shard the offset is the mean of
   ``t_ref(b) - t_shard(b)`` over shared barrier ids — immune to wall-clock
   skew between hosts.
2. **Wall-clock epochs** (fallback): each barrier also records
   ``time.time()``; a shard's epoch (wall time at local ``t=0``) is
   ``mean(wall_b - t_b)``, and offsets are epoch differences.  Only as good
   as NTP, hence the fallback.

Shards with neither stay unaligned (offset 0) and are flagged.

CLI::

    python -m repro.obs.aggregate shard0.jsonl shard1.jsonl \
        [-o merged.jsonl] [--report N] [--summary]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
from typing import Any, Dict, Iterable, List, Optional, Sequence

from ..core.session import (BARRIER_EVENT, EVENT_KINDS, JsonlSink,
                            TraceEvent)

__all__ = ["Shard", "MergedTimeline", "load_shard", "align", "merge",
           "aggregate", "summarize", "main"]


@dataclasses.dataclass
class Shard:
    """One process's slice of the fleet timeline."""

    shard_id: str
    events: List[TraceEvent]            # sorted by local seq
    offset_s: float = 0.0               # aligned_t = t + offset_s
    align_mode: str = "none"            # reference|barrier|wall|none

    @property
    def barriers(self) -> Dict[str, float]:
        """barrier_id -> local session time (first occurrence wins)."""
        out: Dict[str, float] = {}
        for e in self.events:
            if e.name == BARRIER_EVENT and "barrier" in e.meta:
                out.setdefault(str(e.meta["barrier"]), e.t)
        return out

    @property
    def epoch(self) -> Optional[float]:
        """Wall-clock estimate of local ``t=0`` from barrier wall readings."""
        samples = [float(e.meta["wall"]) - e.t for e in self.events
                   if e.name == BARRIER_EVENT and "wall" in e.meta]
        if not samples:
            return None
        return sum(samples) / len(samples)


def _shard_id_from(events: Sequence[TraceEvent], path: str) -> str:
    for e in events:
        host = e.meta.get("host")
        proc = e.meta.get("process")
        if host is not None or proc is not None:
            return f"{host or 'host'}/p{proc if proc is not None else 0}"
    return os.path.splitext(os.path.basename(path))[0]


def load_shard(path: str, shard_id: Optional[str] = None) -> Shard:
    """Read one JSONL shard; events are re-sorted by their local ``seq``
    (shard files may be written out of order by async sinks)."""
    events = sorted(JsonlSink.load(path), key=lambda e: e.seq)
    return Shard(shard_id=shard_id or _shard_id_from(events, path),
                 events=events)


def align(shards: Sequence[Shard]) -> List[Shard]:
    """Solve per-shard clock offsets against ``shards[0]`` (the reference).

    Mutates and returns the shards (offset_s / align_mode filled in).
    """
    if not shards:
        return []
    ref = shards[0]
    ref.offset_s, ref.align_mode = 0.0, "reference"
    ref_b = ref.barriers
    ref_epoch = ref.epoch
    for s in list(shards)[1:]:
        shared = sorted(set(ref_b) & set(s.barriers))
        if shared:
            sb = s.barriers
            s.offset_s = sum(ref_b[b] - sb[b] for b in shared) / len(shared)
            s.align_mode = "barrier"
        elif ref_epoch is not None and s.epoch is not None:
            s.offset_s = s.epoch - ref_epoch
            s.align_mode = "wall"
        else:
            s.offset_s, s.align_mode = 0.0, "none"
    return list(shards)


def merge(shards: Sequence[Shard]) -> "MergedTimeline":
    """Interleave aligned shards into one submission-ordered timeline.

    Every merged event is re-stamped: ``t`` becomes the aligned time,
    ``seq`` the global submission index, and ``meta`` gains
    ``shard``/``src_seq`` so provenance survives the merge.  Ordering is by
    ``(aligned_t, shard_id, local seq)`` — deterministic for any input
    permutation, and a re-merge of the merged output is a fixed point.
    """
    keyed = []
    for s in shards:
        for e in s.events:
            keyed.append((e.t + s.offset_s, s.shard_id, e.seq, e))
    keyed.sort(key=lambda k: k[:3])
    merged: List[TraceEvent] = []
    for gseq, (t_al, sid, sseq, e) in enumerate(keyed):
        meta = dict(e.meta)
        meta.setdefault("shard", sid)
        meta.setdefault("src_seq", sseq)
        merged.append(dataclasses.replace(e, seq=gseq, t=t_al, meta=meta))
    return MergedTimeline(events=merged, shards=list(shards))


def aggregate(paths: Sequence[str]) -> "MergedTimeline":
    """load + align + merge, in one call (the library entry point)."""
    return merge(align([load_shard(p) for p in paths]))


def summarize(events: Iterable[TraceEvent],
              name: str = "aggregate") -> Dict[str, Any]:
    """Session-schema summary recomputed from an event list.

    Same keys as :meth:`TraceSession.summary` (``dropped`` is always 0 —
    whatever reached the shard is what there is; ``wall_s`` is the timeline
    span).  Defined so that, alignment metadata aside, the summary of a
    merged timeline equals the elementwise sum of its shards' summaries.
    """
    evs = list(events)
    by_kind: Dict[str, int] = {}
    kind_dur: Dict[str, float] = {}
    kind_payload: Dict[str, int] = {}
    by_name: Dict[str, Dict[str, Any]] = {}
    payload = 0
    dispatch_s = 0.0
    for e in evs:
        by_kind[e.kind] = by_kind.get(e.kind, 0) + 1
        kind_dur[e.kind] = kind_dur.get(e.kind, 0.0) + e.dur_s
        kind_payload[e.kind] = kind_payload.get(e.kind, 0) + e.payload_bytes
        d = by_name.setdefault(e.name, {"events": 0, "dur_s": 0.0,
                                        "payload_bytes": 0})
        d["events"] += 1
        d["dur_s"] += e.dur_s
        d["payload_bytes"] += e.payload_bytes
        payload += e.payload_bytes
        if e.kind == "dispatch":
            dispatch_s += e.dur_s
    if not evs:
        by_kind = {k: 0 for k in EVENT_KINDS}
        kind_dur = {k: 0.0 for k in EVENT_KINDS}
        kind_payload = {k: 0 for k in EVENT_KINDS}
    return {
        "session": name,
        "events": len(evs),
        "dropped": 0,
        "by_kind": by_kind,
        "dur_s_by_kind": kind_dur,
        "payload_by_kind": kind_payload,
        "by_name": by_name,
        "total_payload_bytes": payload,
        "total_dispatch_s": dispatch_s,
        "wall_s": (max(e.t for e in evs) - min(e.t for e in evs)
                   if evs else 0.0),
    }


@dataclasses.dataclass
class MergedTimeline:
    """The fleet timeline: aligned, interleaved, provenance-tagged."""

    events: List[TraceEvent]
    shards: List[Shard]

    def summary(self) -> Dict[str, Any]:
        s = summarize(self.events, name="aggregate")
        s["alignment"] = {sh.shard_id: {"offset_s": sh.offset_s,
                                        "mode": sh.align_mode,
                                        "events": len(sh.events)}
                          for sh in self.shards}
        return s

    def timeline(self, kinds: Optional[Iterable[str]] = None,
                 shard: Optional[str] = None) -> List[TraceEvent]:
        evs = self.events
        if kinds is not None:
            ks = {kinds} if isinstance(kinds, str) else set(kinds)
            evs = [e for e in evs if e.kind in ks]
        if shard is not None:
            evs = [e for e in evs if e.meta.get("shard") == shard]
        return list(evs)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            for e in self.events:
                f.write(json.dumps(e.to_dict()) + "\n")

    def report(self, max_events: int = 60) -> str:
        lines = [f"==== AGGREGATED TIMELINE ({len(self.shards)} shards, "
                 f"{len(self.events)} events) ===="]
        for sh in self.shards:
            lines.append(f"  shard {sh.shard_id}: {len(sh.events)} events, "
                         f"offset={sh.offset_s*1e3:+.3f}ms "
                         f"({sh.align_mode})")
        lines.append(f"{'seq':>6s}  {'t':>12s}  {'kind':<12s} "
                     f"{'name':<28s} host-cost")
        for e in self.events[:max_events]:
            lines.append(e.describe() + f"  [{e.meta.get('shard')}]")
        if len(self.events) > max_events:
            lines.append(f"  ... {len(self.events) - max_events} more")
        lines.append("==== END AGGREGATED TIMELINE ====")
        return "\n".join(lines)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.aggregate",
        description="Merge per-process TraceSession JSONL shards into one "
                    "cross-host submission-ordered timeline.")
    ap.add_argument("shards", nargs="+", help="per-process .jsonl files")
    ap.add_argument("-o", "--out", default="",
                    help="write the merged timeline as JSONL here")
    ap.add_argument("--report", type=int, default=24, metavar="N",
                    help="print the first N merged events (0 to silence)")
    ap.add_argument("--summary", action="store_true",
                    help="print the merged session-schema summary as JSON")
    args = ap.parse_args(argv)

    merged = aggregate(args.shards)
    if args.report:
        print(merged.report(max_events=args.report))
    if args.summary:
        print(json.dumps(merged.summary(), indent=2, sort_keys=True))
    if args.out:
        merged.save(args.out)
        print(f"wrote {args.out} ({len(merged.events)} events)")
    unaligned = [s.shard_id for s in merged.shards if s.align_mode == "none"]
    if len(merged.shards) > 1 and unaligned:
        print(f"warning: no barrier/wall alignment for {unaligned}; "
              f"their clocks are merged as-is")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
