"""Chrome-trace / Perfetto export of TraceSession timelines.

Turns any event list — a live session ring, a JSONL shard, or the merged
cross-host output of :mod:`repro.obs.aggregate` — into the Chrome Trace
Event JSON that ``ui.perfetto.dev`` (or ``chrome://tracing``) loads
directly, so the paper's Listing-1 timeline becomes a zoomable flame view:

* each **shard** (one process's session) maps to a Perfetto *process*
  (``pid``), named via metadata events from its ``host``/``process`` tags;
* **scoped spans** (``with sess.span(...)``) map to complete duration
  events (``ph: "X"``) on a per-thread track — contextvar scoping
  guarantees proper nesting in time, which Perfetto renders as a stack;
* **unscoped spans** (manual :class:`~repro.core.session.SpanHandle`\\ s,
  e.g. serve requests that overlap arbitrarily) map to *async* event pairs
  (``ph: "b"/"e"`` keyed by span id) so overlap is legal and visible;
* every other event kind rides its own named track: ``dispatch`` events
  with a measurable duration as tiny ``X`` slices, zero-duration ones as
  instants (``ph: "i"``).

CLI::

    python -m repro.obs.export trace.jsonl -o trace_perfetto.json
    python -m repro.obs.export shard.p0.jsonl shard.p1.jsonl -o fleet.json

Multiple inputs are barrier-aligned and merged via
:func:`repro.obs.aggregate.aggregate` first, so one Perfetto view shows the
whole fleet on one clock.
"""
from __future__ import annotations

import argparse
import json
from typing import Any, Dict, Iterable, List, Optional, Sequence

from ..core.session import (BARRIER_EVENT, EVENT_KINDS, SPAN_EVENT,
                            JsonlSink, TraceEvent)

__all__ = ["to_chrome_trace", "export", "main"]

#: tid layout per process: spans stack on low tids (one per emitting
#: thread), event-kind tracks sit above them at a fixed offset
KIND_TID_BASE = 100
_KIND_TID = {k: KIND_TID_BASE + i for i, k in enumerate(EVENT_KINDS)}

#: meta keys that are span/shard plumbing, not useful Perfetto args
_PLUMBING = frozenset({"span_ids", "thread", "scoped", "shard", "src_seq"})


def _shard_key(e: TraceEvent) -> str:
    m = e.meta
    if m.get("shard") is not None:              # aggregate() provenance
        return str(m["shard"])
    host = m.get("host")
    proc = m.get("process")
    if host is not None or proc is not None:
        return f"{host or 'host'}/p{proc if proc is not None else 0}"
    return "local"


def _args_of(e: TraceEvent) -> Dict[str, Any]:
    args: Dict[str, Any] = {"seq": e.seq}
    if e.payload_bytes:
        args["payload_bytes"] = e.payload_bytes
    if e.complete_s:
        args["complete_us"] = round(e.complete_s * 1e6, 3)
    for k, v in e.meta.items():
        if k not in _PLUMBING and isinstance(v, (str, int, float, bool,
                                                 type(None))):
            args[k] = v
    return args


def to_chrome_trace(events: Iterable[TraceEvent],
                    trace_name: str = "repro") -> Dict[str, Any]:
    """Build the Chrome Trace Event JSON object for ``events``.

    Returns the standard object form: ``{"traceEvents": [...],
    "displayTimeUnit": "ms", "otherData": {...}}`` — serializable with
    ``json.dump`` and loadable by Perfetto as-is.
    """
    evs = sorted(events, key=lambda e: (e.t, e.seq))
    out: List[Dict[str, Any]] = []
    pids: Dict[str, int] = {}
    # (pid, python thread ident) -> span tid; tid 0 is the anonymous track
    span_tids: Dict[Any, int] = {}
    kinds_used: Dict[int, set] = {}
    # Perfetto dislikes negative timestamps; rebase if alignment produced
    # any.  Span events are stamped at close time, so their slice *start*
    # (t - dur_s) is what must stay non-negative.
    t_base = min((e.t - (e.dur_s if e.name == SPAN_EVENT else 0.0)
                  for e in evs), default=0.0)
    t_base = t_base if t_base < 0.0 else 0.0

    def pid_of(e: TraceEvent) -> int:
        key = _shard_key(e)
        if key not in pids:
            pids[key] = len(pids)
            out.append({"ph": "M", "name": "process_name", "pid": pids[key],
                        "tid": 0, "args": {"name": key}})
        return pids[key]

    def span_tid(pid: int, thread: Any) -> int:
        key = (pid, thread)
        if key not in span_tids:
            n = sum(1 for (p, _t) in span_tids if p == pid)
            span_tids[key] = n
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": n,
                        "args": {"name": "spans" if n == 0
                                 else f"spans t{n}"}})
        return span_tids[key]

    for e in evs:
        pid = pid_of(e)
        ts = (e.t - t_base) * 1e6                       # microseconds
        if e.name == SPAN_EVENT and "span" in e.meta:
            name = str(e.meta["span"])
            dur = max(e.dur_s, 0.0) * 1e6
            ts = ts - dur       # span events are stamped at close time
            if e.meta.get("scoped"):
                # contextvar spans nest properly in time per thread ->
                # complete events on a shared track render as a stack
                out.append({"ph": "X", "cat": "span", "name": name,
                            "pid": pid,
                            "tid": span_tid(pid, e.meta.get("thread", 0)),
                            "ts": ts, "dur": dur, "args": _args_of(e)})
            else:
                # manual handles overlap arbitrarily -> async pairs
                sid = f"span{e.meta.get('span_id', e.seq)}"
                base = {"cat": "span", "name": name, "pid": pid, "tid": 0,
                        "id": sid}
                out.append({**base, "ph": "b", "ts": ts,
                            "args": _args_of(e)})
                out.append({**base, "ph": "e", "ts": ts + dur, "args": {}})
            continue
        tid = _KIND_TID.get(e.kind, KIND_TID_BASE + len(EVENT_KINDS))
        if tid not in kinds_used.setdefault(pid, set()):
            kinds_used[pid].add(tid)
            out.append({"ph": "M", "name": "thread_name", "pid": pid,
                        "tid": tid, "args": {"name": e.kind}})
        name = e.name
        cat = "barrier" if e.name == BARRIER_EVENT else e.kind
        dur = max(e.dur_s, e.complete_s) * 1e6
        if dur > 0.0:
            out.append({"ph": "X", "cat": cat, "name": name, "pid": pid,
                        "tid": tid, "ts": ts, "dur": dur,
                        "args": _args_of(e)})
        else:
            out.append({"ph": "i", "cat": cat, "name": name, "pid": pid,
                        "tid": tid, "ts": ts, "s": "t",
                        "args": _args_of(e)})
    return {
        "traceEvents": out,
        "displayTimeUnit": "ms",
        "otherData": {"trace": trace_name, "events": len(evs),
                      "shards": sorted(pids)},
    }


def export(paths: Sequence[str], out_path: str,
           trace_name: str = "repro") -> Dict[str, Any]:
    """Load shard(s), merge if several, write Chrome-trace JSON.

    Returns the trace object (also written to ``out_path``).
    """
    if len(paths) == 1:
        events: List[TraceEvent] = sorted(JsonlSink.load(paths[0]),
                                          key=lambda e: e.seq)
    else:
        from .aggregate import aggregate
        events = aggregate(list(paths)).events
    trace = to_chrome_trace(events, trace_name=trace_name)
    with open(out_path, "w") as f:
        json.dump(trace, f)
        f.write("\n")
    return trace


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.export",
        description="Export TraceSession JSONL timeline(s) as Chrome-trace "
                    "JSON for ui.perfetto.dev (several shards are "
                    "barrier-aligned and merged first).")
    ap.add_argument("shards", nargs="+", help="TraceSession .jsonl file(s)")
    ap.add_argument("-o", "--out", default="trace_perfetto.json",
                    help="output Chrome-trace JSON path")
    ap.add_argument("--name", default="repro", help="trace name metadata")
    args = ap.parse_args(argv)

    trace = export(args.shards, args.out, trace_name=args.name)
    n_span = sum(1 for t in trace["traceEvents"]
                 if t.get("cat") == "span" and t["ph"] in ("X", "b"))
    print(f"wrote {args.out}: {len(trace['traceEvents'])} trace events "
          f"({n_span} spans, {len(trace['otherData']['shards'])} "
          f"process(es)) — open at https://ui.perfetto.dev")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
