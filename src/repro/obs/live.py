"""Live summary streaming: watch a running session without stopping it.

:class:`LiveSummary` is a sink that maintains, incrementally and
thread-safely, the *same schema* as :meth:`TraceSession.summary` — so a
poller sees exactly what a post-mortem ``summary()`` would say, just mid
flight.  :class:`ContinuousBatchingServer` installs one on its session and
exposes it via :meth:`live_summary` / :meth:`start_live_endpoint`.

:class:`LiveServer` is the transport: a stdlib ``ThreadingHTTPServer``
(zero dependencies) serving

* ``GET /summary``  — one JSON snapshot (poll mode);
* ``GET /stream``   — newline-delimited JSON snapshots every ``interval``
  seconds (``?interval=0.5&max=0``; ``max=0`` streams until disconnect);
* ``GET /healthz``  — liveness probe.

Used by ``python -m repro.launch.loadtest --live PORT`` and
``python -m repro.launch.serve --live PORT``.
"""
from __future__ import annotations

import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Any, Callable, Dict, Optional
from urllib.parse import parse_qs, urlparse

from ..core.session import EVENT_KINDS, TraceEvent
from .profile import DEFAULT_GROWTH, LogHistogram

__all__ = ["LiveSummary", "LiveServer"]


class LiveSummary:
    """Incremental, thread-safe mirror of ``TraceSession.summary()``.

    Fed as a sink (each ``emit`` folds one event in); :meth:`snapshot`
    returns the accumulated summary under the same keys a session's
    ``summary()`` uses, plus a monotonically increasing ``updates`` counter
    so pollers can cheaply detect change.  Per-kind duration distributions
    are kept in streaming :class:`~repro.obs.profile.LogHistogram`\\ s, so
    ``/summary`` and ``/stream`` report p50/p99 per event kind mid-run
    without ever storing raw samples.
    """

    def __init__(self, name: str = "live",
                 growth: float = DEFAULT_GROWTH) -> None:
        self.name = name
        self.growth = float(growth)
        self._lock = threading.Lock()
        self._t_start = time.perf_counter()
        self._n = 0
        self._by_kind: Dict[str, int] = {}
        self._kind_dur: Dict[str, float] = {}
        self._kind_payload: Dict[str, int] = {}
        self._kind_hist: Dict[str, LogHistogram] = {}
        self._by_name: Dict[str, Dict[str, Any]] = {}
        self._payload = 0
        self._dispatch_s = 0.0

    def emit(self, event: TraceEvent) -> None:
        with self._lock:
            self._n += 1
            k = event.kind
            self._by_kind[k] = self._by_kind.get(k, 0) + 1
            self._kind_dur[k] = self._kind_dur.get(k, 0.0) + event.dur_s
            self._kind_payload[k] = (self._kind_payload.get(k, 0)
                                     + event.payload_bytes)
            hist = self._kind_hist.get(k)
            if hist is None:
                hist = self._kind_hist[k] = LogHistogram(self.growth)
            hist.add(event.dur_s)
            d = self._by_name.setdefault(event.name, {"events": 0,
                                                      "dur_s": 0.0,
                                                      "payload_bytes": 0})
            d["events"] += 1
            d["dur_s"] += event.dur_s
            d["payload_bytes"] += event.payload_bytes
            self._payload += event.payload_bytes
            if k == "dispatch":
                self._dispatch_s += event.dur_s

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            n = self._n
            by_kind = dict(self._by_kind)
            kind_dur = dict(self._kind_dur)
            kind_payload = dict(self._kind_payload)
            percentiles = {k: {"p50": h.percentile(50.0),
                               "p90": h.percentile(90.0),
                               "p99": h.percentile(99.0),
                               "mean": h.mean, "max": h.max}
                           for k, h in self._kind_hist.items()}
            by_name = {k: dict(v) for k, v in self._by_name.items()}
            payload = self._payload
            dispatch_s = self._dispatch_s
        if n == 0:
            by_kind = {k: 0 for k in EVENT_KINDS}
            kind_dur = {k: 0.0 for k in EVENT_KINDS}
            kind_payload = {k: 0 for k in EVENT_KINDS}
        return {
            "session": self.name,
            "events": n,
            "dropped": 0,
            "by_kind": by_kind,
            "dur_s_by_kind": kind_dur,
            "payload_by_kind": kind_payload,
            "dur_percentiles_by_kind": percentiles,
            "by_name": by_name,
            "total_payload_bytes": payload,
            "total_dispatch_s": dispatch_s,
            "wall_s": time.perf_counter() - self._t_start,
            "updates": n,
        }

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {"sink": "LiveSummary", "name": self.name,
                    "events": self._n}

    def close(self) -> None:  # sink protocol
        pass


class LiveServer:
    """Tiny threaded HTTP endpoint around a ``snapshot_fn`` callable."""

    def __init__(self, snapshot_fn: Callable[[], Dict[str, Any]],
                 host: str = "127.0.0.1", port: int = 0) -> None:
        self.snapshot_fn = snapshot_fn
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, *a: Any) -> None:   # silence stderr spam
                pass

            def _json(self, obj: Any, code: int = 200) -> None:
                body = (json.dumps(obj, sort_keys=True) + "\n").encode()
                self.send_response(code)
                self.send_header("Content-Type", "application/json")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def do_GET(self) -> None:
                url = urlparse(self.path)
                if url.path in ("/summary", "/"):
                    self._json(outer.snapshot_fn())
                elif url.path == "/healthz":
                    self._json({"ok": True})
                elif url.path == "/stream":
                    q = parse_qs(url.query)
                    interval = float(q.get("interval", ["0.5"])[0])
                    max_n = int(q.get("max", ["0"])[0])
                    self.send_response(200)
                    self.send_header("Content-Type",
                                     "application/x-ndjson")
                    self.end_headers()
                    sent = 0
                    try:
                        while not outer._stopping.is_set():
                            line = json.dumps(outer.snapshot_fn(),
                                              sort_keys=True) + "\n"
                            self.wfile.write(line.encode())
                            self.wfile.flush()
                            sent += 1
                            if max_n and sent >= max_n:
                                break
                            outer._stopping.wait(interval)
                    except (BrokenPipeError, ConnectionResetError):
                        pass
                else:
                    self._json({"error": f"unknown path {url.path}"},
                               code=404)

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self._stopping = threading.Event()
        self._thread: Optional[threading.Thread] = None

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    @property
    def url(self) -> str:
        host = self._httpd.server_address[0]
        return f"http://{host}:{self.port}"

    def start(self) -> "LiveServer":
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="live-endpoint", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stopping.set()
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
