"""Span-based command attribution: from "what happened" to "why".

The paper's command-stream timeline answers *what* the driver submitted;
performance attribution needs *why* — which request, decode iteration, or
train step caused each doorbell ring, DMA, and graph launch.  Spans
(:meth:`~repro.core.session.TraceSession.span`) stamp that causality onto
every event; this module rolls the stamped timeline up into a
:class:`SpanProfile` — per-span-name command attribution (doorbells,
payload bytes, graph launches, host dispatch time, wall time) with
**streaming log-bucketed histograms** so p50/p90/p99 are available without
ever storing raw samples (the PyGraph/Arafa-style low-overhead
characterization layer).

Two consumption modes, one accumulator:

* **live** — install a :class:`SpanProfile` as a session sink; it folds
  every event in as it is emitted (thread-safe), and :meth:`snapshot`
  answers mid-run;
* **post-mortem** — :meth:`SpanProfile.from_events` over any event list: a
  session ring, a JSONL shard, or the cross-host output of
  :func:`repro.obs.aggregate.aggregate` (span ids are deduplicated per
  shard, so merged fleets profile correctly).

Attribution semantics: an event stamped with span chain ``a -> a/b`` is
credited to *both* paths (roll-up), so a request span sees the doorbells of
its nested decode-iteration spans.  Work shared across spans — one vmapped
decode launch serving many requests — cannot be stamped exclusively; owners
declare each span's share at close time instead
(``handle.end(doorbells=.., payload=..)``), and :class:`SpanProfile` adds
declared attribution on top of stamped attribution.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Any, Dict, Iterable, List, Optional, Tuple

from ..core.session import SPAN_EVENT, TraceEvent

__all__ = ["LogHistogram", "SpanProfile"]

#: default bucket growth factor: representative values are off by at most
#: ``sqrt(growth) - 1`` (~7%) from the true nearest-rank percentile
DEFAULT_GROWTH = 1.15


class LogHistogram:
    """Streaming log-bucketed histogram: percentiles without raw samples.

    Positive values land in geometric buckets ``[growth^i, growth^(i+1))``;
    non-positive values share one exact "zero" bucket.  Memory is O(number
    of occupied buckets) — bounded by the dynamic range, not the sample
    count — so a decode loop can feed one per span name forever.

    :meth:`percentile` returns the geometric midpoint of the bucket holding
    the nearest-rank sample, clamped into the exact observed ``[min, max]``:
    the relative error is at most ``sqrt(growth) - 1``.
    """

    __slots__ = ("growth", "_log_g", "_counts", "_zero", "n", "total",
                 "_min", "_max")

    def __init__(self, growth: float = DEFAULT_GROWTH) -> None:
        if growth <= 1.0:
            raise ValueError(f"growth must be > 1, got {growth}")
        self.growth = float(growth)
        self._log_g = math.log(self.growth)
        self._counts: Dict[int, int] = {}
        self._zero = 0
        self.n = 0
        self.total = 0.0
        self._min = math.inf
        self._max = -math.inf

    def add(self, value: float, count: int = 1) -> None:
        v = float(value)
        self.n += count
        self.total += v * count
        self._min = min(self._min, v)
        self._max = max(self._max, v)
        if v <= 0.0:
            self._zero += count
        else:
            i = math.floor(math.log(v) / self._log_g)
            self._counts[i] = self._counts.get(i, 0) + count

    @property
    def min(self) -> float:
        return 0.0 if self.n == 0 else self._min

    @property
    def max(self) -> float:
        return 0.0 if self.n == 0 else self._max

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile estimate (p in [0, 100])."""
        if self.n == 0:
            return 0.0
        rank = max(1, min(self.n, math.ceil(p / 100.0 * self.n)))
        if rank <= self._zero:
            # non-positive bucket: 0 clamped into the observed range
            return float(min(max(0.0, self._min), self._max))
        seen = self._zero
        for i in sorted(self._counts):
            seen += self._counts[i]
            if seen >= rank:
                rep = self.growth ** (i + 0.5)
                return float(min(max(rep, self._min), self._max))
        return float(self._max)

    def merge(self, other: "LogHistogram") -> "LogHistogram":
        """Fold ``other`` in (bucket-exact when growth factors match)."""
        if other.n == 0:
            return self
        if abs(other.growth - self.growth) > 1e-12:
            raise ValueError(
                f"cannot merge histograms with growth {other.growth} "
                f"into {self.growth}")
        for i, c in other._counts.items():
            self._counts[i] = self._counts.get(i, 0) + c
        self._zero += other._zero
        self.n += other.n
        self.total += other.total
        self._min = min(self._min, other._min)
        self._max = max(self._max, other._max)
        return self

    def summary(self, percentiles: Tuple[float, ...] = (50.0, 90.0, 99.0)
                ) -> Dict[str, float]:
        out = {"n": self.n, "mean": self.mean, "min": self.min,
               "max": self.max}
        for p in percentiles:
            out[f"p{p:g}"] = self.percentile(p)
        return out

    def to_dict(self) -> Dict[str, Any]:
        return {"growth": self.growth, "zero": self._zero, "n": self.n,
                "total": self.total,
                "min": self.min, "max": self.max,
                "counts": {str(i): c for i, c in sorted(self._counts.items())}}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "LogHistogram":
        h = cls(growth=float(d["growth"]))
        h._zero = int(d.get("zero", 0))
        h.n = int(d["n"])
        h.total = float(d.get("total", 0.0))
        if h.n:
            h._min = float(d["min"])
            h._max = float(d["max"])
        h._counts = {int(i): int(c)
                     for i, c in (d.get("counts") or {}).items()}
        return h


@dataclasses.dataclass
class _OpenSpan:
    """Stamped attribution accumulated for one not-yet-closed span."""

    path: str
    events: int = 0
    doorbells: int = 0
    graph_launches: int = 0
    transfers: int = 0
    compiles: int = 0
    payload_bytes: int = 0
    dispatch_s: float = 0.0

    def count(self, e: TraceEvent) -> None:
        self.events += 1
        self.payload_bytes += e.payload_bytes
        if e.kind == "dispatch":
            self.doorbells += 1
            self.dispatch_s += e.dur_s
        elif e.kind == "graph_launch":
            self.graph_launches += 1
        elif e.kind == "transfer":
            self.transfers += 1
        elif e.kind == "compile":
            self.compiles += 1


class _PathStats:
    """Aggregate over all closed spans sharing one ``span_path``."""

    __slots__ = ("spans", "events", "doorbells", "graph_launches",
                 "transfers", "compiles", "payload_bytes", "dispatch_s",
                 "wall_hist", "doorbell_hist", "payload_hist")

    def __init__(self, growth: float) -> None:
        self.spans = 0
        self.events = 0
        self.doorbells = 0
        self.graph_launches = 0
        self.transfers = 0
        self.compiles = 0
        self.payload_bytes = 0
        self.dispatch_s = 0.0
        self.wall_hist = LogHistogram(growth)
        self.doorbell_hist = LogHistogram(growth)
        self.payload_hist = LogHistogram(growth)

    def fold(self, inst: _OpenSpan, wall_s: float) -> None:
        self.spans += 1
        self.events += inst.events
        self.doorbells += inst.doorbells
        self.graph_launches += inst.graph_launches
        self.transfers += inst.transfers
        self.compiles += inst.compiles
        self.payload_bytes += inst.payload_bytes
        self.dispatch_s += inst.dispatch_s
        self.wall_hist.add(wall_s)
        self.doorbell_hist.add(inst.doorbells)
        self.payload_hist.add(inst.payload_bytes)

    def to_dict(self) -> Dict[str, Any]:
        return {
            "spans": self.spans,
            "events": self.events,
            "doorbells": self.doorbells,
            "graph_launches": self.graph_launches,
            "transfers": self.transfers,
            "compiles": self.compiles,
            "payload_bytes": self.payload_bytes,
            "dispatch_s": self.dispatch_s,
            "wall_s": self.wall_hist.summary(),
            "doorbells_per_span": self.doorbell_hist.summary(),
            "payload_bytes_per_span": self.payload_hist.summary(),
        }


class SpanProfile:
    """Per-span-name command attribution over a stamped timeline.

    Feed it events (as a session sink, or offline via
    :meth:`from_events`); read :meth:`snapshot` / :meth:`report`.  Keyed by
    ``span_path`` ("request", "request/decode_iter", ...), with roll-up:
    an event in a nested span is credited to every ancestor on its
    ``span_ids`` chain.  Span identity is deduplicated per shard, so the
    merged output of :func:`repro.obs.aggregate.aggregate` — where two
    processes reuse the same local span ids — profiles correctly.
    """

    def __init__(self, name: str = "profile",
                 growth: float = DEFAULT_GROWTH) -> None:
        self.name = name
        self.growth = float(growth)
        self._lock = threading.Lock()
        # (shard, span_id) -> stamped attribution of a still-open span
        self._open: Dict[Tuple[Any, int], _OpenSpan] = {}
        self._paths: Dict[str, _PathStats] = {}
        self._events_seen = 0

    # -- sink protocol ------------------------------------------------------
    def emit(self, event: TraceEvent) -> None:
        self.feed(event)

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            return {"sink": "SpanProfile", "name": self.name,
                    "events": self._events_seen,
                    "open_spans": len(self._open),
                    "paths": len(self._paths)}

    def close(self) -> None:    # sink protocol
        pass

    # -- accumulation -------------------------------------------------------
    def feed(self, event: TraceEvent) -> None:
        meta = event.meta
        if "span_id" not in meta:
            return
        shard = meta.get("shard")
        with self._lock:
            self._events_seen += 1
            if event.name == SPAN_EVENT and "span" in meta:
                self._close_span(shard, event)
                return
            path = str(meta.get("span_path") or meta.get("span") or "")
            names = path.split("/") if path else []
            ids = meta.get("span_ids") or [meta["span_id"]]
            for depth, sid in enumerate(ids):
                inst = self._open.get((shard, int(sid)))
                if inst is None:
                    inst = _OpenSpan(path="/".join(names[:depth + 1])
                                     or str(meta.get("span", "")))
                    self._open[(shard, int(sid))] = inst
                inst.count(event)

    def _close_span(self, shard: Any, event: TraceEvent) -> None:
        meta = event.meta
        sid = int(meta["span_id"])
        path = str(meta.get("span_path") or meta["span"])
        inst = self._open.pop((shard, sid), None)
        if inst is None:
            inst = _OpenSpan(path=path)
        inst.path = path
        # declared attribution: the owner's share of work that could not be
        # stamped exclusively (e.g. one decode launch serving many requests)
        inst.doorbells += int(meta.get("doorbells", 0))
        inst.payload_bytes += int(meta.get("payload", 0))
        inst.graph_launches += int(meta.get("graph_launches", 0))
        stats = self._paths.get(path)
        if stats is None:
            stats = self._paths[path] = _PathStats(self.growth)
        stats.fold(inst, wall_s=event.dur_s)

    @classmethod
    def from_events(cls, events: Iterable[TraceEvent],
                    name: str = "profile",
                    growth: float = DEFAULT_GROWTH) -> "SpanProfile":
        """Post-mortem profile of any stamped timeline (ring, shard,
        or :func:`~repro.obs.aggregate.aggregate` merge)."""
        prof = cls(name=name, growth=growth)
        for e in events:
            prof.feed(e)
        return prof

    # -- querying -----------------------------------------------------------
    def snapshot(self) -> Dict[str, Any]:
        """JSON-serializable per-path attribution with percentile fields."""
        with self._lock:
            return {
                "profile": self.name,
                "events": self._events_seen,
                "open_spans": len(self._open),
                "spans": {path: st.to_dict()
                          for path, st in sorted(self._paths.items())},
            }

    def path(self, span_path: str) -> Optional[Dict[str, Any]]:
        """One path's stats dict (or None if never closed)."""
        with self._lock:
            st = self._paths.get(span_path)
            return st.to_dict() if st is not None else None

    def store_metrics(self, span_path: Optional[str] = None
                      ) -> Dict[str, float]:
        """Flat ``{metric_id: value}`` view for the metrics store
        (:mod:`repro.obs.store`) — ids are ``path/column`` so
        ``repro.obs.trajectory`` can diff them across runs."""
        out: Dict[str, float] = {}
        for path, st in self.snapshot()["spans"].items():
            if span_path is not None and path != span_path:
                continue
            d = st
            for col in ("spans", "doorbells", "payload_bytes",
                        "graph_launches", "dispatch_s"):
                out[f"{path}/{col}"] = float(d[col])
            for col in ("wall_s", "doorbells_per_span",
                        "payload_bytes_per_span"):
                for pk in ("p50", "p90", "p99", "mean"):
                    out[f"{path}/{col}_{pk}"] = float(d[col][pk])
        return out

    def report(self, max_paths: int = 24) -> str:
        """Fixed-width attribution table (the profiler's Listing-1)."""
        snap = self.snapshot()
        lines = [f"==== SPAN PROFILE {self.name} ====",
                 f"{'span_path':<32s} {'spans':>6s} {'doorbells':>10s} "
                 f"{'payload':>12s} {'glaunch':>8s} "
                 f"{'wall p50':>10s} {'p90':>10s} {'p99':>10s}"]
        for path, st in list(snap["spans"].items())[:max_paths]:
            w = st["wall_s"]
            lines.append(
                f"{path:<32.32s} {st['spans']:>6d} {st['doorbells']:>10d} "
                f"{st['payload_bytes']:>11d}B {st['graph_launches']:>8d} "
                f"{w['p50']*1e3:>8.2f}ms {w['p90']*1e3:>8.2f}ms "
                f"{w['p99']*1e3:>8.2f}ms")
        if len(snap["spans"]) > max_paths:
            lines.append(f"  ... {len(snap['spans']) - max_paths} more")
        if snap["open_spans"]:
            lines.append(f"  ({snap['open_spans']} spans still open)")
        lines.append(f"==== END SPAN PROFILE {self.name} ====")
        return "\n".join(lines)
