"""Production sinks: never block the decode loop, never lose count.

The paper's capture guarantee is *completeness at the commit point*; at
fleet rates the naive way to keep it — synchronous file writes inside
``TraceSession.emit`` — would put disk latency on the doorbell path.  These
sinks trade completeness for boundedness **explicitly**: every event that is
not delivered downstream is *counted*, so the observability loss is itself
observable (``stats()`` rides along in BENCH artifacts and loadtest
records).

* :class:`AsyncSink` — bounded hand-off queue plus a writer thread.  The
  emitting thread only ever enqueues (or, if the queue is full, increments a
  drop counter); the writer thread forwards to the wrapped sink.  Exact
  accounting invariant: ``enqueued + dropped == offered`` always, and after
  ``close()``, ``forwarded == enqueued``.
* :class:`SamplingSink` — deterministic per-kind decimation (keep one event
  in every N of a kind), with exact per-kind counts of what was sampled
  away.  Deterministic (counter-based, not random) so replays and tests see
  identical keeps.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Mapping, Optional

from ..core.session import TraceEvent

__all__ = ["AsyncSink", "SamplingSink"]

_CLOSE = object()       # writer-thread shutdown sentinel


class AsyncSink:
    """Non-blocking wrapper: bounded queue + writer thread + drop accounting.

    ``emit`` never blocks and never touches the wrapped sink: it either
    enqueues the event or — queue full — drops it and counts the drop.  A
    single daemon writer thread drains the queue into ``inner.emit``.

    ``flush()`` waits for the queue to drain (bounded by ``timeout_s``) and
    then flushes the inner sink; ``close()`` drains, stops the writer, and
    closes the inner sink.  Both are safe to call repeatedly.
    """

    def __init__(self, inner: Any, maxsize: int = 8192,
                 name: str = "trace-writer") -> None:
        self.inner = inner
        self.maxsize = int(maxsize)
        self._q: "queue.Queue[Any]" = queue.Queue(maxsize=self.maxsize)
        self._lock = threading.Lock()       # guards the counters only
        self._offered = 0
        self._enqueued = 0
        self._dropped = 0
        self._forwarded = 0
        self._write_errors = 0
        self._closed = False
        self._thread = threading.Thread(target=self._drain, name=name,
                                        daemon=True)
        self._thread.start()

    # -- emitting thread(s) -------------------------------------------------
    def emit(self, event: TraceEvent) -> None:
        with self._lock:
            self._offered += 1
            if self._closed:
                self._dropped += 1
                return
        try:
            self._q.put_nowait(event)
        except queue.Full:
            with self._lock:
                self._dropped += 1
            return
        with self._lock:
            self._enqueued += 1

    # -- writer thread ------------------------------------------------------
    def _drain(self) -> None:
        while True:
            item = self._q.get()
            try:
                if item is _CLOSE:
                    return
                try:
                    self.inner.emit(item)
                    with self._lock:
                        self._forwarded += 1
                except Exception:
                    # a failing backend must not kill the writer thread; the
                    # failure is accounted, not raised into the decode loop
                    with self._lock:
                        self._write_errors += 1
                        self._forwarded += 1
            finally:
                self._q.task_done()

    # -- control ------------------------------------------------------------
    def flush(self, timeout_s: float = 10.0) -> bool:
        """Wait (bounded) for the queue to drain, then flush ``inner``.

        Returns True if the queue fully drained within the timeout.
        """
        deadline = threading.Event()
        waiter = threading.Thread(
            target=lambda: (self._q.join(), deadline.set()), daemon=True)
        waiter.start()
        drained = deadline.wait(timeout_s)
        flush = getattr(self.inner, "flush", None)
        if flush is not None:
            flush()
        return drained

    def close(self, timeout_s: float = 10.0) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
        self._q.put(_CLOSE)             # after _CLOSE, emit() only drops
        self._thread.join(timeout=timeout_s)
        close = getattr(self.inner, "close", None)
        if close is not None:
            close()

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            s = {"sink": "AsyncSink", "maxsize": self.maxsize,
                 "offered": self._offered, "enqueued": self._enqueued,
                 "forwarded": self._forwarded, "dropped": self._dropped,
                 "write_errors": self._write_errors,
                 "pending": self._enqueued - self._forwarded}
        inner_stats = getattr(self.inner, "stats", None)
        if inner_stats is not None:
            s["inner"] = inner_stats()
        return s


class SamplingSink:
    """Deterministic per-kind decimation with exact loss accounting.

    ``every`` maps an event kind to N — keep the 1st, (N+1)th, ... event of
    that kind, sample away the rest; kinds not listed use ``default_every``
    (1 = keep everything).  ``always_names`` lists event names that bypass
    sampling entirely — barrier events default in, because dropping a
    barrier would cost :mod:`repro.obs.aggregate` its clock alignment.
    """

    def __init__(self, inner: Any,
                 every: Optional[Mapping[str, int]] = None,
                 default_every: int = 1,
                 always_names: tuple = ("obs.barrier",)) -> None:
        self.inner = inner
        self.every = {k: max(1, int(n)) for k, n in dict(every or {}).items()}
        self.default_every = max(1, int(default_every))
        self.always_names = tuple(always_names)
        self._lock = threading.Lock()
        self._seen: Dict[str, int] = {}
        self._kept: Dict[str, int] = {}

    def emit(self, event: TraceEvent) -> None:
        with self._lock:
            n = self._seen.get(event.kind, 0)
            self._seen[event.kind] = n + 1
            period = self.every.get(event.kind, self.default_every)
            keep = (event.name in self.always_names) or (n % period == 0)
            if keep:
                self._kept[event.kind] = self._kept.get(event.kind, 0) + 1
        if keep:
            self.inner.emit(event)

    def flush(self) -> None:
        flush = getattr(self.inner, "flush", None)
        if flush is not None:
            flush()

    def close(self) -> None:
        close = getattr(self.inner, "close", None)
        if close is not None:
            close()

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            seen = dict(self._seen)
            kept = dict(self._kept)
        s = {"sink": "SamplingSink",
             "every": dict(self.every), "default_every": self.default_every,
             "seen": seen, "kept": kept,
             "sampled_away": {k: seen[k] - kept.get(k, 0) for k in seen},
             "total_sampled_away": sum(seen.values()) - sum(kept.values())}
        inner_stats = getattr(self.inner, "stats", None)
        if inner_stats is not None:
            s["inner"] = inner_stats()
        return s
