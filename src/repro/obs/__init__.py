"""repro.obs — fleet-wide observability on top of :class:`TraceSession`.

The paper's contribution is *complete capture at the commit point* for one
GPU; this package scales that observation model to the ROADMAP's fleet:

* :mod:`repro.obs.sinks`      — :class:`AsyncSink` (bounded queue + writer
  thread; the decode loop never blocks on trace I/O) and
  :class:`SamplingSink` (deterministic per-kind decimation), both with
  exact drop/sample accounting so observability loss is itself observable;
* :mod:`repro.obs.aggregate`  — merge per-process JSONL shards into one
  cross-host submission-ordered timeline, aligning per-process monotonic
  clocks via shared barrier events (``python -m repro.obs.aggregate``);
* :mod:`repro.obs.live`       — :class:`LiveSummary` (incremental,
  session-schema summary) + :class:`LiveServer` (stdlib HTTP poll/stream
  endpoint the serving engine exposes);
* :mod:`repro.obs.trajectory` — the ``BENCH_<pr>.json`` perf gate:
  per-metric regression detection and a markdown trend report
  (``python -m repro.obs.trajectory``; deterministic count metrics gate
  hard via ``--gate-counts``);
* :mod:`repro.obs.profile`    — :class:`SpanProfile`: per-span causal
  command attribution (doorbells, payload, graph launches per request /
  decode iteration / train step) with streaming :class:`LogHistogram`
  percentiles — no raw samples retained;
* :mod:`repro.obs.export`     — Chrome-trace / Perfetto JSON export of any
  timeline (``python -m repro.obs.export``): scoped spans as nested
  slices, request spans as async pairs, shards as processes;
* :mod:`repro.obs.store`      — :class:`MetricsStore`: append-only
  persistent metrics keyed by (run_id, git_sha, timestamp) under
  ``results/metrics/`` with a query/trend CLI
  (``python -m repro.obs.store``).
"""
from .aggregate import (MergedTimeline, Shard, aggregate, align, load_shard,
                        merge, summarize)
from .export import to_chrome_trace
from .live import LiveServer, LiveSummary
from .profile import LogHistogram, SpanProfile
from .sinks import AsyncSink, SamplingSink
from .store import MetricRecord, MetricsStore

__all__ = [
    "AsyncSink", "SamplingSink",
    "LiveServer", "LiveSummary",
    "LogHistogram", "SpanProfile",
    "MetricRecord", "MetricsStore",
    "MergedTimeline", "Shard", "aggregate", "align", "load_shard", "merge",
    "summarize", "to_chrome_trace",
]
