"""repro.obs — fleet-wide observability on top of :class:`TraceSession`.

The paper's contribution is *complete capture at the commit point* for one
GPU; this package scales that observation model to the ROADMAP's fleet:

* :mod:`repro.obs.sinks`      — :class:`AsyncSink` (bounded queue + writer
  thread; the decode loop never blocks on trace I/O) and
  :class:`SamplingSink` (deterministic per-kind decimation), both with
  exact drop/sample accounting so observability loss is itself observable;
* :mod:`repro.obs.aggregate`  — merge per-process JSONL shards into one
  cross-host submission-ordered timeline, aligning per-process monotonic
  clocks via shared barrier events (``python -m repro.obs.aggregate``);
* :mod:`repro.obs.live`       — :class:`LiveSummary` (incremental,
  session-schema summary) + :class:`LiveServer` (stdlib HTTP poll/stream
  endpoint the serving engine exposes);
* :mod:`repro.obs.trajectory` — the ``BENCH_<pr>.json`` perf gate:
  per-metric regression detection and a markdown trend report
  (``python -m repro.obs.trajectory``).
"""
from .aggregate import (MergedTimeline, Shard, aggregate, align, load_shard,
                        merge, summarize)
from .live import LiveServer, LiveSummary
from .sinks import AsyncSink, SamplingSink

__all__ = [
    "AsyncSink", "SamplingSink",
    "LiveServer", "LiveSummary",
    "MergedTimeline", "Shard", "aggregate", "align", "load_shard", "merge",
    "summarize",
]
