"""BENCH trajectory: diff consecutive ``BENCH_<pr>.json`` artifacts.

``benchmarks/run.py`` emits one machine-readable artifact per PR (section →
typed rows, session summary, tuned-policy objective).  This module turns
that sequence into a *perf gate*: compare a candidate artifact against a
baseline, flag per-metric regressions beyond a threshold, and render a
markdown trend report.  CI runs it after the quick benchmark pass
(warn-only on GPU-less shared runners — quick CPU timings are noisy; count
metrics like doorbells are deterministic and gate hard).

Metric identity is ``section/rowkey/column``; row keys come from the
section's identity cells (``name``/``mode`` strings plus sweep parameters
like ``nbytes``/``chain_len``), so rows match across artifacts even when
row order changes.  Direction (lower- vs higher-is-better) is inferred from
the column name; identity/size columns are never scored.

Warn-only is a *timing* concession, not a blanket one: with
``--gate-counts``, regressions in deterministic count metrics (doorbells,
command footprint bytes, tokens-per-doorbell — exact on any runner) still
fail the run even under ``--warn-only``.  CI uses exactly that split.

CLI::

    python -m repro.obs.trajectory BENCH_6.json BENCH_7.json BENCH_8.json \
        [--threshold 0.25] [--report TREND.md] [--warn-only] [--gate-counts]
    python -m repro.obs.trajectory --baseline BENCH_7.json \
        --candidate BENCH_ci.json --warn-only --gate-counts --report TREND.md
    python -m repro.obs.trajectory --store loadtest [--store-root DIR]

``--store KIND`` diffs the two newest records of ``KIND`` in the
persistent metrics store (:mod:`repro.obs.store`) instead of BENCH
artifacts — same directions, thresholds, and exit codes.

Exit status: 0 clean (or ``--warn-only`` with no enforced count
regressions), 1 regression(s) beyond threshold, 2 usage / unreadable
artifact.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import re
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

__all__ = ["load_artifact", "extract_metrics", "diff_metrics", "Regression",
           "trend_report", "is_count_metric", "main"]

#: columns that identify a row / describe workload size — never scored
SKIP_COLS = frozenset({
    "name", "mode", "nbytes", "chain_len", "steps", "tokens", "requests",
    "new_tokens", "command_bytes_or_bw", "events", "batch", "width",
    "tokens_per_launch", "n",
})
#: substring patterns, checked before the lower-is-better ones
#: ("prefix_hit"/"reused" must win over "pages"/"payload" below: prefix
#: hits and reused pages are the paged-KV savings, more is better)
HIGHER_PATTERNS = ("per_doorbell", "per_s", "bandwidth", "gib",
                   "improvement", "completed", "throughput",
                   "prefix_hit", "reused")
LOWER_PATTERNS = ("latency", "ttft", "overhead", "score", "objective",
                  "dispatch", "doorbell", "final_loss", "evicted",
                  "rejected", "dropped", "payload", "pages",
                  "_us", "_ms", "us", "ms", "wall")


#: deterministic command-stream *count* metrics: exact on any runner, so
#: they gate hard even where timings are warn-only (``--gate-counts``)
COUNT_PATTERNS = ("doorbell", "footprint", "command_bytes", "graph_launch",
                  "rings", "spans", "payload_bytes", "evicted", "rejected",
                  "dropped", "prefix_hit", "pages")
#: anything matching these is a measured quantity, never a count
_TIMING_HINTS = ("per_s", "bandwidth", "gib", "latency", "ttft", "wall",
                 "_us", "_ms")


def direction(col: str) -> Optional[str]:
    """'higher' / 'lower' is better, or None (metric not scored)."""
    c = col.lower()
    if c in SKIP_COLS:
        return None
    for p in HIGHER_PATTERNS:
        if p in c:
            return "higher"
    for p in LOWER_PATTERNS:
        if p in c:
            return "lower"
    if c.endswith("_s"):
        return "lower"
    return None


def is_count_metric(metric: str) -> bool:
    """True for deterministic count metrics (doorbell counts, command
    footprint bytes, tokens-per-doorbell): integer-exact on any runner, so
    a regression there is real no matter how noisy the machine is."""
    col = metric.rsplit("/", 1)[-1].lower()
    if col.endswith(("_s", "_us", "_ms")) or any(h in col
                                                 for h in _TIMING_HINTS):
        return False
    return any(p in col for p in COUNT_PATTERNS)


def load_artifact(path: str) -> Dict[str, Any]:
    with open(path) as f:
        art = json.load(f)
    if "sections" not in art:
        raise ValueError(f"{path}: not a BENCH artifact (no 'sections')")
    art["_path"] = path
    return art


def _row_key(row: Dict[str, Any]) -> str:
    parts = [f"{c}={v}" for c, v in sorted(row.items())
             if isinstance(v, str) or (c in SKIP_COLS and v is not None)]
    return ",".join(parts) or "row"


def extract_metrics(art: Dict[str, Any]) -> Dict[str, Tuple[float, str]]:
    """Flatten an artifact to ``{metric_id: (value, direction)}``.

    Only numeric cells with an inferable direction survive; duplicate row
    keys within a section are dropped (ambiguous identity can't be diffed).
    """
    out: Dict[str, Tuple[float, str]] = {}
    seen_keys: Dict[str, int] = {}
    dupes = set()
    for skey, sec in (art.get("sections") or {}).items():
        for row in sec.get("rows", []):
            rkey = f"{skey}/{_row_key(row)}"
            seen_keys[rkey] = seen_keys.get(rkey, 0) + 1
            if seen_keys[rkey] > 1:
                dupes.add(rkey)
    for skey, sec in (art.get("sections") or {}).items():
        for row in sec.get("rows", []):
            rkey = f"{skey}/{_row_key(row)}"
            if rkey in dupes:
                continue
            for col, val in row.items():
                d = direction(col)
                if d is None or not isinstance(val, (int, float)) \
                        or isinstance(val, bool):
                    continue
                out[f"{rkey}/{col}"] = (float(val), d)
    summ = art.get("session_summary") or {}
    if isinstance(summ.get("total_dispatch_s"), (int, float)):
        out["session/total_dispatch_s"] = (
            float(summ["total_dispatch_s"]), "lower")
    tuning = art.get("tuning") or {}
    if isinstance(tuning.get("after"), (int, float)):
        out["tuning/objective_after"] = (float(tuning["after"]), "lower")
    return out


@dataclasses.dataclass
class Regression:
    metric: str
    base: float
    cand: float
    worsened: float         # fractional change in the "worse" direction
    direction: str

    def describe(self) -> str:
        arrow = "↑" if self.cand >= self.base else "↓"
        return (f"{self.metric}: {self.base:.6g} -> {self.cand:.6g} "
                f"({arrow}{abs(self.worsened)*100:.1f}%, "
                f"{self.direction}-is-better)")


def diff_metrics(base: Dict[str, Tuple[float, str]],
                 cand: Dict[str, Tuple[float, str]],
                 threshold: float = 0.25
                 ) -> Tuple[List[Regression], List[Regression], int]:
    """Compare shared metrics; returns (regressions, improvements, n).

    ``worsened`` is the relative change toward the bad direction; entries
    land in one of the two lists only beyond ``threshold``.  Metrics with a
    zero baseline are skipped (no meaningful relative change).
    """
    regs: List[Regression] = []
    imps: List[Regression] = []
    shared = sorted(set(base) & set(cand))
    for m in shared:
        b, d = base[m]
        c, _ = cand[m]
        if b == 0.0:
            continue
        rel = (c - b) / abs(b)
        worsened = rel if d == "lower" else -rel
        r = Regression(metric=m, base=b, cand=c, worsened=worsened,
                       direction=d)
        if worsened > threshold:
            regs.append(r)
        elif worsened < -threshold:
            imps.append(r)
    regs.sort(key=lambda r: -r.worsened)
    imps.sort(key=lambda r: r.worsened)
    return regs, imps, len(shared)


def _headline(art: Dict[str, Any]) -> Dict[str, Any]:
    summ = art.get("session_summary") or {}
    tuning = art.get("tuning") or {}
    return {
        "pr": art.get("pr"),
        "file": art.get("_path", "?"),
        "quick": art.get("quick"),
        "arch": art.get("arch"),
        "events": summ.get("events"),
        "total_dispatch_s": summ.get("total_dispatch_s"),
        "objective_after": tuning.get("after"),
    }


def trend_report(arts: Sequence[Dict[str, Any]], threshold: float,
                 max_rows: int = 40) -> Tuple[str, List[Regression]]:
    """Markdown trend over a PR-ordered artifact sequence.

    Returns (markdown, regressions-of-the-final-pair) — the final pair is
    the gate (newest committed baseline vs fresh candidate).
    """
    lines = ["# BENCH trajectory report", "",
             f"generated: {time.strftime('%Y-%m-%dT%H:%M:%S')}  ·  "
             f"threshold: {threshold*100:.0f}%", ""]
    lines += ["## Artifacts", "",
              "| pr | file | quick | arch | events | total_dispatch_s | "
              "objective_after |",
              "|---|---|---|---|---|---|---|"]
    for art in arts:
        h = _headline(art)
        disp = (f"{h['total_dispatch_s']:.4g}"
                if isinstance(h["total_dispatch_s"], float) else "—")
        obj = (f"{h['objective_after']:.4g}"
               if isinstance(h["objective_after"], float) else "—")
        lines.append(f"| {h['pr']} | `{h['file']}` | {h['quick']} | "
                     f"{h['arch']} | {h['events']} | {disp} | {obj} |")
    lines.append("")

    gate_regs: List[Regression] = []
    for base, cand in zip(arts, arts[1:]):
        regs, imps, n = diff_metrics(extract_metrics(base),
                                     extract_metrics(cand), threshold)
        pair = (f"pr {base.get('pr')} → pr {cand.get('pr')} "
                f"(`{base.get('_path')}` → `{cand.get('_path')}`)")
        lines += [f"## {pair}", ""]
        if base.get("quick") != cand.get("quick"):
            lines += ["> **note:** quick/full scale mismatch between the "
                      "two artifacts — timing deltas are not comparable; "
                      "treat this diff as informational.", ""]
        lines.append(f"{n} shared metrics · {len(regs)} regressed · "
                     f"{len(imps)} improved (beyond threshold)")
        lines.append("")
        if regs or imps:
            lines += ["| metric | base | candidate | change | verdict |",
                      "|---|---|---|---|---|"]
            for r in (regs + imps)[:max_rows]:
                verdict = ("**REGRESSION**" if r.worsened > 0
                           else "improvement")
                lines.append(
                    f"| `{r.metric}` | {r.base:.6g} | {r.cand:.6g} | "
                    f"{(r.cand - r.base)/abs(r.base)*100:+.1f}% | "
                    f"{verdict} |")
            if len(regs) + len(imps) > max_rows:
                lines.append(f"| … {len(regs) + len(imps) - max_rows} "
                             f"more | | | | |")
        lines.append("")
        gate_regs = regs            # last pair wins: that is the gate
    return "\n".join(lines), gate_regs


def _pr_of(path: str) -> Tuple[int, str]:
    m = re.search(r"(\d+)", os.path.basename(path))
    return (int(m.group(1)) if m else 1 << 30, path)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.trajectory",
        description="Diff BENCH_<pr>.json artifacts; gate on regressions.")
    ap.add_argument("artifacts", nargs="*",
                    help="artifact files, diffed consecutively in PR order")
    ap.add_argument("--baseline", default="",
                    help="explicit baseline (with --candidate)")
    ap.add_argument("--candidate", default="",
                    help="explicit candidate (with --baseline)")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="fractional regression threshold (default 0.25)")
    ap.add_argument("--report", default="",
                    help="write the markdown trend report here")
    ap.add_argument("--warn-only", action="store_true",
                    help="report regressions but exit 0 (noisy runners)")
    ap.add_argument("--gate-counts", action="store_true",
                    help="deterministic count metrics (doorbells, command "
                         "footprint) fail the run even under --warn-only")
    ap.add_argument("--store", default="", metavar="KIND",
                    help="diff the two newest records of KIND from the "
                         "persistent metrics store instead of artifacts")
    ap.add_argument("--store-root", default=None, metavar="DIR",
                    help="metrics store root (default results/metrics or "
                         "REPRO_METRICS_DIR)")
    args = ap.parse_args(argv)

    if args.store:
        if args.artifacts or args.baseline or args.candidate:
            ap.error("--store replaces artifact arguments")
        from .store import MetricsStore
        store = MetricsStore(root=args.store_root)
        recs = store.records(args.store)
        if len(recs) < 2:
            print(f"trajectory: need >= 2 stored {args.store!r} records "
                  f"in {store.root}, have {len(recs)}")
            return 2
        base_r, cand_r = recs[-2], recs[-1]

        def as_scored(rec) -> Dict[str, Tuple[float, str]]:
            out: Dict[str, Tuple[float, str]] = {}
            for k, v in rec.metrics.items():
                if not isinstance(v, (int, float)) or isinstance(v, bool):
                    continue
                d = direction(k.rsplit("/", 1)[-1])
                if d is not None:
                    out[k] = (float(v), d)
            return out

        regs, imps, n = diff_metrics(as_scored(base_r), as_scored(cand_r),
                                     threshold=args.threshold)
        print(f"store {args.store!r}: {base_r.run_id} ({base_r.git_sha}) "
              f"-> {cand_r.run_id} ({cand_r.git_sha}), "
              f"{n} shared metrics")
        for r in imps:
            print(f"improvement {r.describe()}")
        return _gate_exit(regs, args)

    paths = list(args.artifacts)
    if args.baseline or args.candidate:
        if not (args.baseline and args.candidate) or paths:
            ap.error("--baseline/--candidate are used together, without "
                     "positional artifacts")
        paths = [args.baseline, args.candidate]
    else:
        paths.sort(key=_pr_of)
    if len(paths) < 2:
        ap.error("need at least two artifacts to diff")

    try:
        arts = [load_artifact(p) for p in paths]
    except (OSError, ValueError, json.JSONDecodeError) as e:
        print(f"trajectory: cannot load artifact: {e}")
        return 2

    md, gate_regs = trend_report(arts, threshold=args.threshold)
    if args.report:
        with open(args.report, "w") as f:
            f.write(md + "\n")
        print(f"wrote {args.report}")
    return _gate_exit(gate_regs, args)


def _gate_exit(gate_regs: List[Regression], args: argparse.Namespace) -> int:
    """Shared verdict: print regressions, apply the warn-only/count split."""
    count_regs = [r for r in gate_regs if is_count_metric(r.metric)]
    for r in gate_regs:
        kind = "COUNT " if r in count_regs else ""
        print(f"{kind}REGRESSION {r.describe()}")
    if not gate_regs:
        print("trajectory: no regressions beyond threshold in the gate pair")
        return 0
    enforced = (not args.warn_only) or (args.gate_counts
                                        and bool(count_regs))
    detail = ""
    if args.warn_only:
        detail = (f" [warn-only, but {len(count_regs)} deterministic "
                  f"count regression(s) gate hard]"
                  if enforced else " [warn-only]")
    print(f"trajectory: {len(gate_regs)} regression(s) beyond "
          f"{args.threshold*100:.0f}% in the gate pair{detail}")
    return 1 if enforced else 0


if __name__ == "__main__":
    raise SystemExit(main())
