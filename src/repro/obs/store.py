"""Persistent metrics store: append-only, per-kind JSONL under
``results/metrics/``.

Per-run JSONL traces answer "what happened in *this* run"; the ROADMAP's
trajectory question — is tokens-per-doorbell trending the right way across
PRs, machines, and weeks — needs runs to outlive their processes.  This
store is the minimal durable layer: every record is one JSON line keyed by
``(run_id, git_sha, timestamp)`` with a flat ``{metric_id: value}`` payload,
appended (never rewritten) to ``<root>/<kind>.jsonl``.

Writers: ``benchmarks/run.py`` (kind ``bench``, the flattened BENCH
artifact), ``python -m repro.launch.loadtest`` (kinds ``loadtest`` and
``span_profile``), and anything else with a dict of numbers.  Readers: the
query/trend CLI below, and ``python -m repro.obs.trajectory --store``,
which replays the stored sequence through the same regression gate it runs
on BENCH artifacts.

CLI::

    python -m repro.obs.store list  [--kind bench] [--root DIR]
    python -m repro.obs.store show  RUN_ID [--kind bench]
    python -m repro.obs.store trend --kind loadtest \
        [--keys latency_p50_s,tokens_per_s] [--last 10] [--markdown]

``REPRO_METRICS_DIR`` overrides the root; ``REPRO_RUN_ID`` pins the run id
(so a launcher can stamp every artifact of one run identically).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence

__all__ = ["MetricRecord", "MetricsStore", "default_root", "git_sha",
           "new_run_id", "main"]

_GIT_SHA_CACHE: Optional[str] = None


def default_root() -> str:
    return os.environ.get("REPRO_METRICS_DIR",
                          os.path.join("results", "metrics"))


def git_sha() -> str:
    """The repo HEAD sha (cached; ``REPRO_GIT_SHA`` env override; falls
    back to ``"unknown"`` outside a git checkout)."""
    global _GIT_SHA_CACHE
    if _GIT_SHA_CACHE is None:
        env = os.environ.get("REPRO_GIT_SHA")
        if env:
            _GIT_SHA_CACHE = env
        else:
            try:
                _GIT_SHA_CACHE = subprocess.run(
                    ["git", "rev-parse", "--short=12", "HEAD"],
                    capture_output=True, text=True, timeout=5,
                    check=True).stdout.strip() or "unknown"
            except Exception:
                _GIT_SHA_CACHE = "unknown"
    return _GIT_SHA_CACHE


def new_run_id() -> str:
    """``REPRO_RUN_ID`` if set, else a sortable timestamp-pid id."""
    return os.environ.get(
        "REPRO_RUN_ID",
        f"{time.strftime('%Y%m%dT%H%M%S')}-p{os.getpid()}")


@dataclasses.dataclass(frozen=True)
class MetricRecord:
    """One stored measurement set: who, when, at which commit, what."""

    run_id: str
    git_sha: str
    ts: float                       # epoch seconds
    kind: str                       # store file: <kind>.jsonl
    metrics: Dict[str, Any]         # flat {metric_id: number}
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return {"run_id": self.run_id, "git_sha": self.git_sha,
                "ts": self.ts, "kind": self.kind, "metrics": self.metrics,
                "meta": self.meta}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "MetricRecord":
        return cls(run_id=str(d["run_id"]), git_sha=str(d.get("git_sha", "")),
                   ts=float(d["ts"]), kind=str(d["kind"]),
                   metrics=dict(d.get("metrics") or {}),
                   meta=dict(d.get("meta") or {}))


class MetricsStore:
    """Append-only metrics store rooted at ``results/metrics/`` by default.

    Appends are atomic at line granularity (single ``write`` of one
    ``\\n``-terminated line on a file opened in append mode); reads tolerate
    a truncated trailing line the same way shard aggregation does, so a
    crashed writer never poisons the store.
    """

    def __init__(self, root: Optional[str] = None) -> None:
        self.root = root or default_root()

    def _path(self, kind: str) -> str:
        safe = "".join(c if (c.isalnum() or c in "-_.") else "_"
                       for c in kind) or "misc"
        return os.path.join(self.root, f"{safe}.jsonl")

    # -- writing ------------------------------------------------------------
    def append(self, kind: str, metrics: Dict[str, Any],
               run_id: Optional[str] = None,
               meta: Optional[Dict[str, Any]] = None,
               ts: Optional[float] = None) -> MetricRecord:
        """Record one measurement set; returns the stored record."""
        rec = MetricRecord(run_id=run_id or new_run_id(), git_sha=git_sha(),
                           ts=time.time() if ts is None else float(ts),
                           kind=kind, metrics=dict(metrics),
                           meta=dict(meta or {}))
        os.makedirs(self.root, exist_ok=True)
        with open(self._path(kind), "a") as f:
            f.write(json.dumps(rec.to_dict(), sort_keys=True) + "\n")
        return rec

    # -- reading ------------------------------------------------------------
    def kinds(self) -> List[str]:
        if not os.path.isdir(self.root):
            return []
        return sorted(os.path.splitext(f)[0] for f in os.listdir(self.root)
                      if f.endswith(".jsonl"))

    def records(self, kind: str, run_id: Optional[str] = None,
                since: Optional[float] = None) -> List[MetricRecord]:
        """Stored records of ``kind``, oldest first (append order)."""
        path = self._path(kind)
        if not os.path.exists(path):
            return []
        with open(path) as f:
            lines = f.read().splitlines()
        out: List[MetricRecord] = []
        for i, line in enumerate(lines):
            line = line.strip()
            if not line:
                continue
            try:
                rec = MetricRecord.from_dict(json.loads(line))
            except (json.JSONDecodeError, KeyError, TypeError, ValueError):
                if any(l.strip() for l in lines[i + 1:]):
                    raise
                break               # truncated trailing line: crashed writer
            if run_id is not None and rec.run_id != run_id:
                continue
            if since is not None and rec.ts < since:
                continue
            out.append(rec)
        return out

    def latest(self, kind: str) -> Optional[MetricRecord]:
        recs = self.records(kind)
        return recs[-1] if recs else None

    # -- trend views --------------------------------------------------------
    def trend(self, kind: str, keys: Optional[Sequence[str]] = None,
              last: int = 10, markdown: bool = False) -> str:
        """Cross-run table of selected metrics, oldest -> newest.

        ``keys`` default to the (up to 8) numeric metric ids shared by the
        newest record; direction arrows come from
        :func:`repro.obs.trajectory.direction` so a reader sees at a glance
        which way each column *should* move.
        """
        from .trajectory import direction
        recs = self.records(kind)[-max(1, int(last)):]
        if not recs:
            return f"(no records of kind {kind!r} in {self.root})"
        if not keys:
            newest = recs[-1]
            keys = [k for k, v in sorted(newest.metrics.items())
                    if isinstance(v, (int, float))
                    and not isinstance(v, bool)][:8]
        keys = list(keys)

        def arrow(k: str) -> str:
            d = direction(k.rsplit("/", 1)[-1])
            return {"higher": "↑", "lower": "↓"}.get(d or "", "")

        heads = ["run_id", "git_sha", "when"] + [f"{k}{arrow(k)}"
                                                 for k in keys]
        rows = []
        for r in recs:
            when = time.strftime("%m-%d %H:%M", time.localtime(r.ts))
            cells = [r.run_id, r.git_sha, when]
            for k in keys:
                v = r.metrics.get(k)
                cells.append(f"{v:.6g}" if isinstance(v, (int, float))
                             and not isinstance(v, bool) else "—")
            rows.append(cells)
        if markdown:
            lines = ["| " + " | ".join(heads) + " |",
                     "|" + "---|" * len(heads)]
            lines += ["| " + " | ".join(r) + " |" for r in rows]
            return "\n".join(lines)
        widths = [max(len(h), *(len(r[i]) for r in rows))
                  for i, h in enumerate(heads)]
        lines = ["  ".join(h.ljust(w) for h, w in zip(heads, widths))]
        lines += ["  ".join(c.ljust(w) for c, w in zip(r, widths))
                  for r in rows]
        return "\n".join(lines)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.store",
        description="Query the persistent metrics store "
                    "(results/metrics/*.jsonl).")
    ap.add_argument("--root", default=None,
                    help=f"store root (default {default_root()})")
    sub = ap.add_subparsers(dest="cmd", required=True)
    p_list = sub.add_parser("list", help="list kinds / records")
    p_list.add_argument("--kind", default="")
    p_show = sub.add_parser("show", help="print one run's records as JSON")
    p_show.add_argument("run_id")
    p_show.add_argument("--kind", default="")
    p_trend = sub.add_parser("trend", help="cross-run metric trend table")
    p_trend.add_argument("--kind", required=True)
    p_trend.add_argument("--keys", default="",
                         help="comma-separated metric ids (default: auto)")
    p_trend.add_argument("--last", type=int, default=10)
    p_trend.add_argument("--markdown", action="store_true")
    args = ap.parse_args(argv)

    store = MetricsStore(root=args.root)
    if args.cmd == "list":
        kinds = [args.kind] if args.kind else store.kinds()
        if not kinds:
            print(f"(empty store at {store.root})")
            return 0
        for k in kinds:
            recs = store.records(k)
            print(f"{k}: {len(recs)} record(s)")
            for r in recs[-5:]:
                print(f"  {r.run_id}  {r.git_sha}  "
                      f"{time.strftime('%Y-%m-%d %H:%M', time.localtime(r.ts))}"
                      f"  {len(r.metrics)} metrics")
        return 0
    if args.cmd == "show":
        kinds = [args.kind] if args.kind else store.kinds()
        found = [r for k in kinds for r in store.records(k,
                                                         run_id=args.run_id)]
        if not found:
            print(f"no records for run_id {args.run_id!r}")
            return 1
        for r in found:
            print(json.dumps(r.to_dict(), indent=2, sort_keys=True))
        return 0
    keys = [k for k in args.keys.split(",") if k] or None
    print(store.trend(args.kind, keys=keys, last=args.last,
                      markdown=args.markdown))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
