"""Deterministic synthetic data pipeline with per-host sharding + prefetch.

Every batch is a pure function of (seed, step, host) — restart-safe: after a
checkpoint restore at step k the pipeline regenerates exactly the batches it
would have produced, which is what makes checkpoint/restart exact (see
runtime/fault_tolerance.py).  A background thread prefetches ahead of the
training loop so host data work overlaps device compute — the same
submission-overlap lesson as the paper's pipelined pushbuffer writes.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterator, Optional

import numpy as np

from ..configs.base import ModelConfig
from ..configs.shapes import ShapeConfig

__all__ = ["SyntheticTokens", "Prefetcher", "make_pipeline"]


class SyntheticTokens:
    """Zipf-ish synthetic token stream (deterministic per step/host)."""

    def __init__(self, cfg: ModelConfig, shape: ShapeConfig, seed: int = 0,
                 host_id: int = 0, n_hosts: int = 1) -> None:
        assert shape.global_batch % n_hosts == 0
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.host_batch = shape.global_batch // n_hosts

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg, shape = self.cfg, self.shape
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 65_537 + self.host_id)
        B, S = self.host_batch, shape.seq_len
        # zipf-like marginal over the real (unpadded) vocab
        u = rng.random((B, S + 1))
        toks = np.minimum((cfg.vocab_size * u ** 2.2).astype(np.int32),
                          cfg.vocab_size - 1)
        out: Dict[str, np.ndarray] = {}
        if cfg.family == "audio":
            S_dec = max(S // cfg.enc_seq_ratio, 1)
            out["frames"] = rng.standard_normal(
                (B, S, cfg.d_model)).astype(np.float32)
            out["tokens"] = toks[:, :S_dec]
            out["labels"] = toks[:, 1:S_dec + 1]
        elif cfg.family == "vlm":
            S_text = S - cfg.n_patches
            out["patch_embeds"] = rng.standard_normal(
                (B, cfg.n_patches, cfg.d_model)).astype(np.float32)
            out["tokens"] = toks[:, :S_text]
            out["labels"] = toks[:, 1:S_text + 1]
        else:
            out["tokens"] = toks[:, :S]
            out["labels"] = toks[:, 1:S + 1]
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch of a step-indexed dataset."""

    def __init__(self, dataset: SyntheticTokens, start_step: int = 0,
                 depth: int = 2) -> None:
        self.dataset = dataset
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        step = self._step
        while not self._stop.is_set():
            batch = self.dataset.batch_at(step)
            while not self._stop.is_set():
                try:
                    self.q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self, timeout: float = 30.0):
        return self.q.get(timeout=timeout)

    def stop(self) -> None:
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)


def make_pipeline(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0,
                  host_id: int = 0, n_hosts: int = 1,
                  start_step: int = 0, prefetch: int = 2) -> Prefetcher:
    return Prefetcher(SyntheticTokens(cfg, shape, seed, host_id, n_hosts),
                      start_step=start_step, depth=prefetch)
