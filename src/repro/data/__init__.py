from .pipeline import Prefetcher, SyntheticTokens, make_pipeline

__all__ = ["Prefetcher", "SyntheticTokens", "make_pipeline"]
