"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp

__all__ = ["linear_warmup", "cosine_schedule"]


def linear_warmup(step, warmup: int, peak: float):
    s = jnp.asarray(step, jnp.float32)
    return peak * jnp.minimum(1.0, s / jnp.maximum(1.0, float(warmup)))


def cosine_schedule(step, warmup: int, total: int, peak: float,
                    floor: float = 0.1):
    s = jnp.asarray(step, jnp.float32)
    warm = peak * jnp.minimum(1.0, s / jnp.maximum(1.0, float(warmup)))
    t = jnp.clip((s - warmup) / jnp.maximum(1.0, float(total - warmup)), 0, 1)
    cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(s < warmup, warm, cos)
