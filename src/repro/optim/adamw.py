"""AdamW with bf16 params + fp32 master/moments (mixed-precision training).

State layout is a plain pytree mirroring the parameter tree, so the
ZeRO-1 sharding rules (``ShardingRules.opt_specs``) apply leaf-by-leaf.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWState", "adamw_init", "adamw_update", "clip_by_global_norm"]

Params = Any


@dataclasses.dataclass
class AdamWState:
    """Pytree-registered optimizer state."""

    step: jax.Array
    master: Params               # fp32 master weights
    m: Params
    v: Params

    def tree_flatten(self):
        return (self.step, self.master, self.m, self.v), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    AdamWState,
    lambda s: s.tree_flatten(),
    lambda aux, c: AdamWState.tree_unflatten(aux, c))


def adamw_init(params: Params) -> AdamWState:
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        master=jax.tree_util.tree_map(f32, params),
        m=jax.tree_util.tree_map(zeros, params),
        v=jax.tree_util.tree_map(zeros, params))


def clip_by_global_norm(grads: Params, max_norm: float
                        ) -> Tuple[Params, jax.Array]:
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def adamw_update(grads: Params, state: AdamWState, params: Params,
                 lr: jax.Array, b1: float = 0.9, b2: float = 0.95,
                 eps: float = 1e-8, weight_decay: float = 0.1,
                 max_grad_norm: float = 1.0
                 ) -> Tuple[Params, AdamWState, Dict[str, jax.Array]]:
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, master):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / c1
        vh = v / c2
        new_master = master - lr * (mh / (jnp.sqrt(vh) + eps)
                                    + weight_decay * master)
        return m, v, new_master

    flat = jax.tree_util.tree_map(upd, grads, state.m, state.v, state.master)
    new_m = jax.tree_util.tree_map(lambda t: t[0], flat,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[1], flat,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_master = jax.tree_util.tree_map(lambda t: t[2], flat,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree_util.tree_map(
        lambda mw, p: mw.astype(p.dtype), new_master, params)
    new_state = AdamWState(step=step, master=new_master, m=new_m, v=new_v)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
