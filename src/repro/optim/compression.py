"""Gradient compression for the data-parallel all-reduce.

At pod scale the DP all-reduce of grok/llama-sized gradients dominates ICI
traffic (the roofline collective term).  int8 block-quantized compression
with error feedback cuts the all-reduce payload 2x vs bf16 while error
feedback keeps the quantization noise from accumulating (Seide et al.;
1-bit Adam lineage).

The compressed representative is a (int8 values, fp32 per-block scales)
pair; ``ef_compress_update`` is the drop-in used by the Trainer when
``grad_compression=int8`` is enabled.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp

__all__ = ["compress_int8", "decompress_int8", "ErrorFeedbackState",
           "ef_init", "ef_compress_update"]

Params = Any
BLOCK = 256


def _pad_to_block(x: jax.Array) -> Tuple[jax.Array, int]:
    flat = x.reshape(-1)
    pad = (-flat.size) % BLOCK
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, BLOCK), pad


def compress_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Block-quantize to (int8 [N/B, B], scales fp32 [N/B])."""
    blocks, _ = _pad_to_block(x.astype(jnp.float32))
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127).astype(jnp.int8)
    return q, scale


def decompress_int8(q: jax.Array, scale: jax.Array, shape, dtype
                    ) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


@dataclasses.dataclass
class ErrorFeedbackState:
    residual: Params

    def tree_flatten(self):
        return (self.residual,), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)


jax.tree_util.register_pytree_node(
    ErrorFeedbackState,
    lambda s: s.tree_flatten(),
    lambda aux, c: ErrorFeedbackState.tree_unflatten(aux, c))


def ef_init(params: Params) -> ErrorFeedbackState:
    return ErrorFeedbackState(residual=jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params))


def ef_compress_update(grads: Params, ef: ErrorFeedbackState
                       ) -> Tuple[Params, ErrorFeedbackState]:
    """Compress+decompress each grad leaf with error feedback.

    The round-trip models the all-reduce payload being int8 on the wire;
    the quantization error is carried to the next step instead of lost.
    """
    def one(g, r):
        target = g.astype(jnp.float32) + r
        q, s = compress_int8(target)
        restored = decompress_int8(q, s, g.shape, jnp.float32)
        return restored.astype(g.dtype), target - restored

    out = jax.tree_util.tree_map(one, grads, ef.residual)
    new_g = jax.tree_util.tree_map(lambda t: t[0], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_r = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return new_g, ErrorFeedbackState(residual=new_r)
