"""Synthetic serving traffic: seeded Poisson arrivals + replay harness.

Production decode traffic is not a static batch: requests arrive on their
own clock with mixed prompt and output lengths, join a running batch, and
leave when done.  This module generates that pattern deterministically (one
``numpy`` Generator seed fixes the arrival times, prompts, and budgets) and
replays it against a :class:`~repro.runtime.server.ContinuousBatchingServer`
either in real time (a producer thread sleeps to each arrival and submits
while the decode loop runs — the regime the thread-safe ``TraceSession``
exists for) or synchronously (submit everything, then drain — deterministic
scheduling for tests and the tuner).

Replay metrics come from one place: the engine's run metrics, which are
TraceSession deltas (doorbells = ``dispatch`` events, tokens carried on
``serve.finish`` progress payloads) plus per-ticket latency percentiles.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .scheduler import RequestTicket
from .server import ContinuousBatchingServer, Request

__all__ = ["TrafficSpec", "Arrival", "generate", "replay"]


@dataclasses.dataclass(frozen=True)
class TrafficSpec:
    """Shape of a synthetic load: Poisson arrivals, mixed lengths.

    ``rate`` is the mean arrival rate in requests/second (inter-arrival
    gaps are exponential); prompt and output lengths are drawn uniformly
    from the given choices.  Keeping ``prompt_lens`` a small discrete set
    bounds prefill compilation to one compile per distinct length.
    """

    n_requests: int = 64
    rate: float = 50.0
    prompt_lens: Tuple[int, ...] = (4, 8, 16)
    new_tokens: Tuple[int, ...] = (4, 8, 16)
    seed: int = 0
    #: tokens of seeded prefix shared by every prompt (0 = independent
    #: prompts); models system-prompt traffic, the regime where the paged
    #: KV backend's prefix-page reuse pays off
    prefix_len: int = 0

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One scheduled request: submit at ``t`` seconds after replay start."""

    t: float
    request: Request


def generate(spec: TrafficSpec, vocab_size: int) -> List[Arrival]:
    """Deterministic schedule: same spec (incl. seed) -> same arrivals."""
    rng = np.random.default_rng(spec.seed)
    prefix = rng.integers(0, vocab_size, size=spec.prefix_len
                          ).astype(np.int32)
    arrivals: List[Arrival] = []
    t = 0.0
    for uid in range(spec.n_requests):
        t += float(rng.exponential(1.0 / spec.rate))
        plen = int(rng.choice(spec.prompt_lens))
        budget = int(rng.choice(spec.new_tokens))
        suffix = rng.integers(0, vocab_size, size=plen).astype(np.int32)
        arrivals.append(Arrival(t=t, request=Request(
            uid=uid, prompt=np.concatenate([prefix, suffix]),
            max_new_tokens=budget)))
    return arrivals


def replay(engine: ContinuousBatchingServer, arrivals: Sequence[Arrival],
           realtime: bool = True, speed: float = 1.0,
           idle_timeout_s: float = 30.0
           ) -> Tuple[List[RequestTicket], Dict[str, Any]]:
    """Drive ``arrivals`` through the engine; returns (tickets, metrics).

    ``realtime=True`` submits from a producer thread that sleeps to each
    (speed-scaled) arrival time while the caller's thread runs the decode
    loop — requests genuinely join mid-decode.  ``realtime=False`` submits
    everything up front (arrival order preserved, zero wall-clock gaps):
    fully deterministic scheduling, used by tests and the tuner.
    """
    if not realtime:
        # everything is already queued: drain and exit as soon as idle
        tickets = [engine.submit(a.request) for a in arrivals]
        metrics = engine.run(idle_timeout_s=0.0)
        return tickets, metrics

    tickets: List[RequestTicket] = []

    def producer() -> None:
        t0 = time.perf_counter()
        for a in arrivals:
            delay = a.t / speed - (time.perf_counter() - t0)
            if delay > 0:
                time.sleep(delay)
            tickets.append(engine.submit(a.request))
        engine.close_intake()

    thread = threading.Thread(target=producer, name="traffic", daemon=True)
    thread.start()
    metrics = engine.run(idle_timeout_s=idle_timeout_s)
    thread.join()
    return tickets, metrics
