"""Serving runtime: batched prefill + decode with submission accounting.

Decode is the pathological small-submission regime the paper's DMA study
targets: one token of useful work per dispatch.  The server therefore
exposes ``tokens_per_launch`` (multi-token graph launch — scan T decode
steps into one dispatch) and tracks doorbells so the benefit is measurable.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.session import TraceSession
from ..models import get_model

__all__ = ["Server", "Request"]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int = 16
    tokens: Optional[List[int]] = None


class Server:
    def __init__(self, cfg: ModelConfig, batch_size: int, max_seq: int,
                 tokens_per_launch: Optional[int] = None, seed: int = 0,
                 session: Optional[TraceSession] = None) -> None:
        self.cfg = cfg
        self.B = batch_size
        self.max_seq = max_seq
        # ``tokens_per_launch=None`` -> auto-apply the tuned policy for this
        # (model config, platform, device count), if one is persisted; an
        # explicit value always wins (repro.tune is the tuner that writes
        # these policies).
        self.policy = None
        if tokens_per_launch is None:
            from ..tune.policy import load_policy_for
            self.policy = load_policy_for(cfg)
            tokens_per_launch = (self.policy.knob("tokens_per_launch", 1)
                                 if self.policy else 1)
        self.T = max(1, int(tokens_per_launch))
        self.model = get_model(cfg)
        # Shared timeline: pass a session to merge serving events with a
        # trainer's or a benchmark's; otherwise the server owns one.
        self.session = session or TraceSession(name="server")
        self.tracker = self.session.doorbell
        self.params = self.model.init_params(jax.random.PRNGKey(seed))

        self._prefill = self.tracker.wrap(
            jax.jit(lambda p, toks: self.model.prefill(p, toks, max_seq)),
            "prefill")

        if self.T == 1:
            self._decode = self.tracker.wrap(
                jax.jit(self.model.decode_step), "decode_step")
        else:
            def decode_T(params, state, tokens):
                def body(carry, _):
                    st, tok = carry
                    st, logits = self.model.decode_step(params, st, tok)
                    nxt = jnp.argmax(logits[:, -1:, :], axis=-1).astype(
                        tok.dtype)
                    return (st, nxt), nxt[:, 0]
                (state, _), toks = jax.lax.scan(
                    body, (state, tokens), None, length=self.T)
                return state, toks  # [T, B]

            self._decode_T = self.tracker.wrap(jax.jit(decode_T),
                                               "decode_T_steps")

    def serve(self, requests: List[Request]) -> Dict[str, Any]:
        """Greedy-decode a batch of requests (padded to server batch)."""
        assert len(requests) <= self.B
        for r in requests:
            if len(r.prompt) > self.max_seq:
                raise ValueError(
                    f"request {r.uid}: prompt length {len(r.prompt)} exceeds "
                    f"max_seq={self.max_seq}; the decode state would overrun")
        S = max(len(r.prompt) for r in requests)
        toks = np.zeros((self.B, S), np.int32)
        for i, r in enumerate(requests):
            toks[i, S - len(r.prompt):] = r.prompt      # left-pad
        t0 = time.perf_counter()
        # session may be shared with other consumers: report per-run deltas
        db0 = self.tracker.count
        ev0 = self.session.n_events
        state, logits = self._prefill(self.params, jnp.asarray(toks))
        nxt = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        max_new = max(r.max_new_tokens for r in requests)
        out = [nxt[:, 0]]
        produced = 1
        while produced < max_new:
            if self.T == 1:
                state, logits = self._decode(self.params, state, nxt)
                nxt = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
                out.append(nxt[:, 0])
                produced += 1
            else:
                state, tok_block = self._decode_T(self.params, state, nxt)
                # the launch always scans T steps, but only the un-truncated
                # prefix is useful output — account for exactly that many
                take = min(self.T, max_new - produced)
                for t in range(take):
                    out.append(tok_block[t])
                nxt = tok_block[-1][:, None].astype(jnp.int32)
                produced += take
        jax.block_until_ready(out[-1])
        wall = time.perf_counter() - t0
        tokens = np.stack([np.asarray(t) for t in out], axis=1)  # [B, new]
        for i, r in enumerate(requests):
            r.tokens = tokens[i, :r.max_new_tokens].tolist()
        doorbells = self.tracker.count - db0
        # useful tokens = what each request asked for, NOT max_new * B:
        # heterogeneous requests decode to the batch max but only keep their
        # own budget, and the tuner's objective reads exactly these fields.
        new_tokens = int(sum(r.max_new_tokens for r in requests))
        return {
            "wall_s": wall,
            "doorbells": doorbells,
            "new_tokens": new_tokens,
            "tokens_per_doorbell": new_tokens / max(1, doorbells),
            "trace_events": self.session.n_events - ev0,
        }
