"""Serving runtime: batched prefill + decode with submission accounting.

Decode is the pathological small-submission regime the paper's DMA study
targets: one token of useful work per dispatch.  The server therefore
exposes ``tokens_per_launch`` (multi-token graph launch — scan T decode
steps into one dispatch) and tracks doorbells so the benefit is measurable.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.session import TraceSession
from ..models import get_model

__all__ = ["Server", "Request"]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int = 16
    tokens: Optional[List[int]] = None


class Server:
    def __init__(self, cfg: ModelConfig, batch_size: int, max_seq: int,
                 tokens_per_launch: int = 1, seed: int = 0,
                 session: Optional[TraceSession] = None) -> None:
        self.cfg = cfg
        self.B = batch_size
        self.max_seq = max_seq
        self.T = max(1, tokens_per_launch)
        self.model = get_model(cfg)
        # Shared timeline: pass a session to merge serving events with a
        # trainer's or a benchmark's; otherwise the server owns one.
        self.session = session or TraceSession(name="server")
        self.tracker = self.session.doorbell
        self.params = self.model.init_params(jax.random.PRNGKey(seed))

        self._prefill = self.tracker.wrap(
            jax.jit(lambda p, toks: self.model.prefill(p, toks, max_seq)),
            "prefill")

        if self.T == 1:
            self._decode = self.tracker.wrap(
                jax.jit(self.model.decode_step), "decode_step")
        else:
            def decode_T(params, state, tokens):
                def body(carry, _):
                    st, tok = carry
                    st, logits = self.model.decode_step(params, st, tok)
                    nxt = jnp.argmax(logits[:, -1:, :], axis=-1).astype(
                        tok.dtype)
                    return (st, nxt), nxt[:, 0]
                (state, _), toks = jax.lax.scan(
                    body, (state, tokens), None, length=self.T)
                return state, toks  # [T, B]

            self._decode_T = self.tracker.wrap(jax.jit(decode_T),
                                               "decode_T_steps")

    def serve(self, requests: List[Request]) -> Dict[str, Any]:
        """Greedy-decode a batch of requests (padded to server batch)."""
        assert len(requests) <= self.B
        S = max(len(r.prompt) for r in requests)
        toks = np.zeros((self.B, S), np.int32)
        for i, r in enumerate(requests):
            toks[i, S - len(r.prompt):] = r.prompt      # left-pad
        t0 = time.perf_counter()
        # session may be shared with other consumers: report per-run deltas
        db0 = self.tracker.count
        ev0 = self.session.n_events
        state, logits = self._prefill(self.params, jnp.asarray(toks))
        nxt = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        max_new = max(r.max_new_tokens for r in requests)
        out = [nxt[:, 0]]
        produced = 1
        while produced < max_new:
            if self.T == 1:
                state, logits = self._decode(self.params, state, nxt)
                nxt = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
                out.append(nxt[:, 0])
                produced += 1
            else:
                state, tok_block = self._decode_T(self.params, state, nxt)
                for t in range(min(self.T, max_new - produced)):
                    out.append(tok_block[t])
                nxt = tok_block[-1][:, None].astype(jnp.int32)
                produced += self.T
        jax.block_until_ready(out[-1])
        wall = time.perf_counter() - t0
        tokens = np.stack([np.asarray(t) for t in out], axis=1)  # [B, new]
        for i, r in enumerate(requests):
            r.tokens = tokens[i, :r.max_new_tokens].tolist()
        doorbells = self.tracker.count - db0
        return {
            "wall_s": wall,
            "doorbells": doorbells,
            "new_tokens": int(min(produced, max_new)) * len(requests),
            "tokens_per_doorbell":
                min(produced, max_new) * len(requests)
                / max(1, doorbells),
            "trace_events": self.session.n_events - ev0,
        }
