"""Serving runtime: batched prefill + decode with submission accounting.

Decode is the pathological small-submission regime the paper's DMA study
targets: one token of useful work per dispatch.  The server therefore
exposes ``tokens_per_launch`` (multi-token graph launch — scan T decode
steps into one dispatch) and tracks doorbells so the benefit is measurable.

Two serving surfaces share one model/params/session:

* :class:`Server.serve` — one-shot: a static batch decodes to completion.
* :class:`ContinuousBatchingServer` — a request queue with admission
  control and eviction, per-request KV slots, and a decode loop that new
  requests *join while it runs* (and leave mid-stream) without ever
  recompiling the graph-launched multi-token decode.

The continuous engine keeps one decode state **per slot** (each slot is a
full batch-1 state pytree, stacked on a fresh leading axis and driven by a
``jax.vmap`` over slots).  Each slot therefore carries its own cache length
and its own greedy chain — a request's tokens are *independent of batch
composition and join time*, which is what makes continuous-batching output
exactly equal to a one-shot ``serve()`` of the same request.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.session import SpanHandle, TraceSession
from ..models import get_model
from .scheduler import (AdmissionQueue, RequestTicket, latency_stats,
                        make_policy)

__all__ = ["Server", "Request", "ContinuousBatchingServer"]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int = 16
    tokens: Optional[List[int]] = None
    priority: int = 0           # PriorityPolicy: higher admits first
    user: str = ""              # FairSharePolicy: least-served user first


def _empty_metrics() -> Dict[str, Any]:
    return {"wall_s": 0.0, "doorbells": 0, "new_tokens": 0,
            "tokens_per_doorbell": 0.0, "trace_events": 0}


class Server:
    def __init__(self, cfg: ModelConfig, batch_size: int, max_seq: int,
                 tokens_per_launch: Optional[int] = None, seed: int = 0,
                 session: Optional[TraceSession] = None) -> None:
        self.cfg = cfg
        self.B = batch_size
        self.max_seq = max_seq
        # ``tokens_per_launch=None`` -> auto-apply the tuned policy for this
        # (model config, platform, device count), if one is persisted; an
        # explicit value always wins (repro.tune is the tuner that writes
        # these policies).
        self.policy = None
        if tokens_per_launch is None:
            from ..tune.policy import load_policy_for
            self.policy = load_policy_for(cfg)
            tokens_per_launch = (self.policy.knob("tokens_per_launch", 1)
                                 if self.policy else 1)
        self.T = max(1, int(tokens_per_launch))
        self.model = get_model(cfg)
        # Shared timeline: pass a session to merge serving events with a
        # trainer's or a benchmark's; otherwise the server owns one.
        self.session = session or TraceSession(name="server")
        self.tracker = self.session.doorbell
        self.params = self.model.init_params(jax.random.PRNGKey(seed))

        self._prefill = self.tracker.wrap(
            jax.jit(lambda p, toks: self.model.prefill(p, toks, max_seq)),
            "prefill")

        if self.T == 1:
            self._decode = self.tracker.wrap(
                jax.jit(self.model.decode_step), "decode_step")
        else:
            def decode_T(params, state, tokens):
                def body(carry, _):
                    st, tok = carry
                    st, logits = self.model.decode_step(params, st, tok)
                    nxt = jnp.argmax(logits[:, -1:, :], axis=-1).astype(
                        tok.dtype)
                    return (st, nxt), nxt[:, 0]
                (state, _), toks = jax.lax.scan(
                    body, (state, tokens), None, length=self.T)
                return state, toks  # [T, B]

            self._decode_T = self.tracker.wrap(jax.jit(decode_T),
                                               "decode_T_steps")

    def _decode_block(self, state, nxt, want: int
                      ) -> Tuple[Any, List[jax.Array], jax.Array]:
        """One multi-token graph launch; keep only ``want`` tokens.

        The launch always scans ``self.T`` steps; when ``want < T`` the
        block is truncated and only the prefix is useful output.  Returns
        ``(state, tokens, continuation)`` where ``continuation`` is the
        last *kept* token (``tok_block[take - 1]``, not ``tok_block[-1]``
        — a truncated block's final token is past the useful prefix, so a
        re-entered decode loop must not continue from it).
        """
        state, tok_block = self._decode_T(self.params, state, nxt)
        take = min(self.T, want)
        toks = [tok_block[t] for t in range(take)]
        nxt = tok_block[take - 1][:, None].astype(jnp.int32)
        return state, toks, nxt

    def serve(self, requests: List[Request]) -> Dict[str, Any]:
        """Greedy-decode a batch of requests (padded to server batch)."""
        if not requests:
            return _empty_metrics()
        if len(requests) > self.B:
            raise ValueError(
                f"got {len(requests)} requests for batch_size={self.B}; "
                f"use ContinuousBatchingServer for queued admission")
        for r in requests:
            if len(r.prompt) > self.max_seq:
                raise ValueError(
                    f"request {r.uid}: prompt length {len(r.prompt)} exceeds "
                    f"max_seq={self.max_seq}; the decode state would overrun")
        S = max(len(r.prompt) for r in requests)
        toks = np.zeros((self.B, S), np.int32)
        for i, r in enumerate(requests):
            toks[i, S - len(r.prompt):] = r.prompt      # left-pad
        t0 = time.perf_counter()
        # session may be shared with other consumers: report per-run deltas
        db0 = self.tracker.count
        ev0 = self.session.n_events
        max_new = max(r.max_new_tokens for r in requests)
        with self.session.span("serve.oneshot", batch=len(requests),
                               max_new=max_new):
            with self.session.span("serve.prefill", seq_len=S):
                state, logits = self._prefill(self.params, jnp.asarray(toks))
            nxt = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
            out = [nxt[:, 0]]
            produced = 1
            while produced < max_new:
                with self.session.span("serve.decode_iter",
                                       produced=produced):
                    if self.T == 1:
                        state, logits = self._decode(self.params, state, nxt)
                        nxt = jnp.argmax(logits[:, -1:, :],
                                         axis=-1).astype(jnp.int32)
                        out.append(nxt[:, 0])
                        produced += 1
                    else:
                        state, block, nxt = self._decode_block(
                            state, nxt, max_new - produced)
                        out.extend(block)
                        produced += len(block)
            jax.block_until_ready(out[-1])
        wall = time.perf_counter() - t0
        tokens = np.stack([np.asarray(t) for t in out], axis=1)  # [B, new]
        for i, r in enumerate(requests):
            r.tokens = tokens[i, :r.max_new_tokens].tolist()
        doorbells = self.tracker.count - db0
        # useful tokens = what each request asked for, NOT max_new * B:
        # heterogeneous requests decode to the batch max but only keep their
        # own budget, and the tuner's objective reads exactly these fields.
        new_tokens = int(sum(r.max_new_tokens for r in requests))
        return {
            "wall_s": wall,
            "doorbells": doorbells,
            "new_tokens": new_tokens,
            "tokens_per_doorbell": new_tokens / max(1, doorbells),
            "trace_events": self.session.n_events - ev0,
        }


class ContinuousBatchingServer(Server):
    """Continuous-batching inference engine on top of :class:`Server`.

    Requests are :meth:`submit`-ted (thread-safe — a traffic-generator
    thread can feed a running decode loop) into a bounded
    :class:`~repro.runtime.scheduler.AdmissionQueue`; :meth:`run` drives
    the decode loop, admitting queued requests into free KV slots *between
    decode launches* so the jitted, graph-launched ``tokens_per_launch``
    decode never changes shape (and never recompiles) across join/leave
    boundaries.

    Per-request state: slot ``i`` holds a complete batch-1 decode-state
    pytree (own KV cache, own cache ``length``); the engine stacks all
    ``batch_size`` slot states on a new leading axis and decodes them with
    one ``vmap``-ed launch.  Prefill runs per admitted request at its exact
    prompt length (compiled once per distinct length), so a request's
    greedy chain is bit-identical to ``Server.serve([request])`` no matter
    when it joined or who shared the batch.

    Lifecycle events land on the session timeline as ``progress`` events
    (``serve.submit/admit/finish/evict/reject``); a finish event carries
    the emitted tokens as its payload (4 bytes each), so token throughput
    is recoverable from session accounting alone.

    Observability plane: the engine installs a
    :class:`~repro.obs.LiveSummary` sink on its session, so
    :meth:`live_summary` answers at any point *during* a run with the same
    schema ``session.summary()`` gives post-mortem (plus engine state:
    active slots, queue depth, ticket fates).  :meth:`start_live_endpoint`
    serves that over HTTP (``GET /summary``, ``GET /stream``) — the
    loadtest harness exposes it with ``--live``.
    """

    def __init__(self, cfg: ModelConfig, batch_size: int, max_seq: int,
                 tokens_per_launch: Optional[int] = None, seed: int = 0,
                 session: Optional[TraceSession] = None,
                 max_pending: int = 256,
                 admission: str = "reject",
                 kv: str = "dense",
                 kv_page_tokens: Optional[int] = None,
                 kv_pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 sched: str = "fifo") -> None:
        super().__init__(cfg, batch_size, max_seq,
                         tokens_per_launch=tokens_per_launch, seed=seed,
                         session=session)
        self.queue = AdmissionQueue(max_pending=max_pending, policy=admission)
        self.sched_policy = make_policy(sched)
        self.tickets: List[RequestTicket] = []      # submit order, all fates
        self._slot_tix: List[Optional[RequestTicket]] = [None] * self.B
        self._prefilling: set = set()               # slots mid-chunked-prefill
        self._prefill_rr = 0                        # round-robin tick cursor
        # per-request causal spans: a request's lifetime crosses scheduler
        # iterations (and the decode launch is shared by every active slot),
        # so these are manual handles closed in _finish with *declared*
        # attribution — n_launches decode launches + prefill launches
        self._req_spans: Dict[int, SpanHandle] = {}

        # live observability plane: every event the (possibly shared)
        # session emits while this engine exists also folds into an
        # incremental summary a poller can read mid-run
        from ..obs.live import LiveSummary
        self.live = LiveSummary(name=self.session.name)
        self.session.add_sink(self.live)
        self._live_server: Optional[Any] = None

        # KV backend: dense (stacked per-slot states, the PR-7 layout) or
        # paged (global page pool + block tables + shared-prefix reuse).
        # Unset knobs fall back to the tuned policy for this config.
        if kv == "paged" and kv_page_tokens is None:
            kv_page_tokens = int(self.policy.knob("kv_page_tokens", 16)
                                 if self.policy else 16)
        if prefill_chunk is None:
            prefill_chunk = int(self.policy.knob("prefill_chunk", 0)
                                if self.policy else 0)
        from .kv import make_kv
        # None -> default; explicit invalid values (e.g. 0) must reach
        # kv_geometry's validation instead of being silently coerced
        self.kv = make_kv(
            self, kv,
            page_tokens=16 if kv_page_tokens is None else kv_page_tokens,
            pages=kv_pages, prefill_chunk=prefill_chunk)

    @property
    def _decode_slots(self):
        """The backend's vmapped decode launch (tests inspect its compile
        cache to prove shape stability across churn)."""
        return self.kv._decode_slots

    # -- intake (any thread) ----------------------------------------------
    def submit(self, request: Request) -> RequestTicket:
        """Enqueue a request; returns its ticket (possibly already
        ``rejected`` — admission control, not an exception, because the
        traffic thread must keep running)."""
        tix = RequestTicket(request=request, t_submit=time.perf_counter())
        if len(request.prompt) > self.max_seq:
            tix.status, tix.reason = "rejected", "prompt_exceeds_max_seq"
            tix.t_done = tix.t_submit
        else:
            accepted, dropped = self.queue.submit(tix)
            if dropped is not None:
                dropped.status, dropped.reason = "evicted", "queue_overflow"
                dropped.t_done = time.perf_counter()
                self.session.emit("progress", "serve.evict",
                                  uid=dropped.uid, reason=dropped.reason)
                self._end_request_span(dropped)
            if not accepted:
                tix.status = "rejected"
                tix.reason = ("intake_closed" if self.queue.closed
                              else "queue_full")
                tix.t_done = time.perf_counter()
            else:
                self._req_spans[tix.uid] = self.session.start_span(
                    "serve.request", uid=tix.uid,
                    prompt_len=int(len(request.prompt)))
        self.tickets.append(tix)
        name = "serve.submit" if not tix.finished else "serve.reject"
        self.session.emit("progress", name, uid=tix.uid, status=tix.status,
                          reason=tix.reason)
        return tix

    def close_intake(self) -> None:
        """No more submits: :meth:`run` may exit once everything drains."""
        self.queue.close()

    # -- live observability (any thread) -----------------------------------
    def live_summary(self) -> Dict[str, Any]:
        """Session-schema summary *now*, plus engine state.

        Safe from any thread while the decode loop runs; this is the
        poll-mode payload of the live endpoint.
        """
        snap = self.live.snapshot()
        tickets = list(self.tickets)
        snap["engine"] = {
            "slots": self.B,
            "active": self.n_active,
            "queued": len(self.queue),
            "intake_closed": self.queue.closed,
            "tickets": {s: sum(1 for t in tickets if t.status == s)
                        for s in ("queued", "active", "done", "evicted",
                                  "rejected")},
            "tokens_emitted": sum(len(t.tokens) for t in tickets),
        }
        return snap

    def start_live_endpoint(self, port: int = 0, host: str = "127.0.0.1"):
        """Serve :meth:`live_summary` over HTTP; returns the started
        :class:`~repro.obs.LiveServer` (``.url``, ``.stop()``)."""
        from ..obs.live import LiveServer
        self._live_server = LiveServer(self.live_summary, host=host,
                                       port=port).start()
        return self._live_server

    def stop_live_endpoint(self) -> None:
        if self._live_server is not None:
            self._live_server.stop()
            self._live_server = None

    # -- scheduling (decode-loop thread) -----------------------------------
    def _free_slots(self) -> List[int]:
        return [i for i, t in enumerate(self._slot_tix) if t is None]

    @property
    def n_active(self) -> int:
        return sum(1 for t in self._slot_tix if t is not None)

    def _end_request_span(self, tix: RequestTicket) -> None:
        """Close a request's causal span with its declared attribution.

        The vmapped decode launch is shared by every active slot, so this
        request's share of the command stream can't be read off stamped
        events — it is *declared* here instead: one doorbell per decode
        launch the request rode (``n_launches``) plus its prefill, and
        4 bytes per emitted token (matching the finish-event payload).
        """
        handle = self._req_spans.pop(tix.uid, None)
        if handle is None:
            return
        launches = tix.n_launches
        handle.end(uid=tix.uid, status=tix.status, slot=tix.slot,
                   n_tokens=len(tix.tokens),
                   doorbells=launches + tix.n_prefill_launches,
                   graph_launches=launches,
                   payload=4 * len(tix.tokens))

    def _on_first_token(self, tix: RequestTicket, tok0: int) -> None:
        """Prefill completed: record token 0, finish degenerate requests."""
        self._prefilling.discard(tix.slot)
        tix.tokens.append(tok0)
        tix.t_first = time.perf_counter()
        if len(tix.tokens) >= min(tix.request.max_new_tokens, tix.cap):
            self._finish(tix)           # degenerate 1-token request

    def _admit(self) -> int:
        """Move queued tickets into free slots.

        Whole-prompt admission (no chunking) prefills synchronously here —
        the pre-refactor behavior.  Prompts longer than the backend's
        ``prefill_chunk`` only *start* here; :meth:`_prefill_tick` advances
        them one bounded launch per scheduler iteration so active slots
        keep decoding underneath.
        """
        admitted = 0
        for slot in self._free_slots():
            tix = self.queue.pop(self.sched_policy)
            if tix is None:
                break
            r = tix.request
            if not self.kv.begin(slot, tix):
                # page pool exhausted even after reclaiming shared pages
                tix.status, tix.reason = "evicted", "kv_pages"
                tix.t_done = time.perf_counter()
                self.session.emit("progress", "serve.evict", uid=tix.uid,
                                  reason=tix.reason)
                self._end_request_span(tix)
                self.sched_policy.note_finished(tix)
                continue
            tix.status, tix.slot = "active", slot
            tix.t_admit = time.perf_counter()
            # KV capacity: decode token j (0-based; token 0 comes straight
            # from prefill logits) writes cache position prompt_len + j - 1,
            # which must stay below max_seq.
            tix.cap = self.max_seq - len(r.prompt) + 1
            self._slot_tix[slot] = tix
            self._prefilling.add(slot)
            chunk = self.kv.chunk
            if not (chunk and len(r.prompt) > chunk):
                tok0 = self.kv.prefill_step(slot)   # one whole-prompt launch
                self.session.emit("progress", "serve.admit", uid=tix.uid,
                                  slot=slot,
                                  queued_s=tix.t_admit - tix.t_submit)
                self._on_first_token(tix, tok0)
            else:
                self.session.emit("progress", "serve.admit", uid=tix.uid,
                                  slot=slot,
                                  queued_s=tix.t_admit - tix.t_submit)
            admitted += 1
        return admitted

    def _prefill_tick(self) -> None:
        """Advance at most ONE pending chunked prefill by one launch.

        One bounded launch per scheduler iteration keeps the decode-iter
        gap under control (the acceptance bar: no gap beyond 2x the median
        decode-iter duration); round-robin across prefilling slots keeps
        long prompts from starving each other.
        """
        pending = sorted(s for s in self._prefilling
                         if self._slot_tix[s] is not None)
        if not pending:
            return
        slot = pending[self._prefill_rr % len(pending)]
        self._prefill_rr += 1
        tok0 = self.kv.prefill_step(slot)
        if tok0 is not None:
            self._on_first_token(self._slot_tix[slot], tok0)

    def _finish(self, tix: RequestTicket, reason: Optional[str] = None
                ) -> None:
        evicted = (reason is not None
                   or len(tix.tokens) < tix.request.max_new_tokens)
        tix.status = "evicted" if evicted else "done"
        if evicted:
            tix.reason = reason or "kv_overrun"
        tix.t_done = time.perf_counter()
        tix.request.tokens = list(tix.tokens)
        self._slot_tix[tix.slot] = None
        self._prefilling.discard(tix.slot)
        self.kv.release(tix.slot)
        self.session.emit(
            "progress", "serve.evict" if evicted else "serve.finish",
            payload_bytes=4 * len(tix.tokens), uid=tix.uid, slot=tix.slot,
            tokens=len(tix.tokens), latency_s=tix.latency_s,
            **({"reason": tix.reason} if evicted else {}))
        self._end_request_span(tix)
        self.sched_policy.note_finished(tix)

    def step(self) -> bool:
        """One scheduler iteration: admit, advance one chunked prefill,
        then one decode launch across all decodable slots; harvest per-slot
        tokens.  Returns False if idle."""
        self._admit()
        self._prefill_tick()
        decodable = [slot for slot, tix in enumerate(self._slot_tix)
                     if tix is not None and slot not in self._prefilling]
        if not decodable:
            return self.n_active > 0    # prefills pending still count
        # paged backend: grow block tables for the coming T writes; slots
        # the pool cannot serve are evicted (reason="kv_pages") and their
        # freed pages immediately retried for the survivors
        while True:
            victims = self.kv.reserve_decode(decodable)
            if not victims:
                break
            for slot in victims:
                self._finish(self._slot_tix[slot], reason="kv_pages")
                decodable.remove(slot)
            if not decodable:
                return self.n_active > 0
        with self.session.span("serve.decode_iter", active=self.n_active):
            blocks = self.kv.decode()               # [B, T] host sync
            for slot in decodable:
                tix = self._slot_tix[slot]
                tix.n_launches += 1
                budget = min(tix.request.max_new_tokens, tix.cap)
                take = min(self.T, budget - len(tix.tokens))
                tix.tokens.extend(int(t) for t in blocks[slot, :take])
                if len(tix.tokens) >= budget:
                    self._finish(tix)
        return True

    def run(self, idle_timeout_s: float = 5.0,
            poll_s: float = 0.0005) -> Dict[str, Any]:
        """Drive the decode loop until all work drains.

        Exits when no request is queued or active AND either the intake is
        closed (threaded replay calls :meth:`close_intake` when the
        producer finishes) or nothing has arrived for ``idle_timeout_s``
        (synchronous submit-then-run callers never close the intake).
        When idle, the loop blocks on the queue's condition variable —
        :meth:`submit` and :meth:`close_intake` wake it immediately —
        with ``poll_s`` as the floor fallback timeout instead of the old
        ``sleep(poll_s)`` spin.  Returns run metrics; per-request detail
        lives on the tickets.
        """
        t0 = time.perf_counter()
        db0, ev0 = self.tracker.count, self.session.n_events
        # snapshot: the tickets list grows from the traffic thread mid-run
        done0 = sum(1 for t in list(self.tickets) if t.t_done >= 0)
        tok0 = sum(len(t.tokens) for t in list(self.tickets))
        idle_since: Optional[float] = None
        while True:
            if self.step():
                idle_since = None
                continue
            if len(self.queue) == 0:
                if self.queue.closed:
                    break
                now = time.perf_counter()
                idle_since = idle_since if idle_since is not None else now
                remaining = idle_timeout_s - (now - idle_since)
                if remaining <= 0:
                    break
                self.queue.wait_for_work(timeout=max(poll_s, remaining))
            else:
                # queued work raced in after this iteration's admit pass;
                # loop around immediately
                continue
        wall = time.perf_counter() - t0
        tickets = list(self.tickets)
        ended = [t for t in tickets if t.t_done >= t0]
        by_status = {s: sum(1 for t in ended if t.status == s)
                     for s in ("done", "evicted", "rejected")}
        new_tokens = sum(len(t.tokens) for t in tickets) - tok0
        doorbells = self.tracker.count - db0
        out = {
            "wall_s": wall,
            "requests": sum(1 for t in tickets if t.t_done >= 0) - done0,
            "completed": by_status["done"],
            "evicted": by_status["evicted"],
            "rejected": by_status["rejected"],
            "new_tokens": int(new_tokens),
            "doorbells": doorbells,
            "tokens_per_doorbell": new_tokens / max(1, doorbells),
            "tokens_per_s": new_tokens / max(wall, 1e-9),
            "trace_events": self.session.n_events - ev0,
            # backend memory-path accounting (pages, prefix hits, prefill
            # launches/bytes) — engine-lifetime totals, not per-run deltas
            "kv": self.kv.stats(),
        }
        # latency percentiles over requests that actually decoded; instant
        # rejections would skew p50 toward zero
        out.update(latency_stats(
            [t for t in ended if t.status in ("done", "evicted")]))
        return out
