"""Fault tolerance: heartbeats, straggler mitigation, restart, elastic re-mesh.

Built on the paper's progress-tracker primitive (core/semaphore.py): a
worker's step completion *is* its heartbeat, exactly like a semaphore
release proves command completion within a channel.

Policies (all exercised by tests with injected failures):

* **straggler detection** — workers whose inter-beat interval lags the fleet
  median by ``straggler_factor`` are flagged; mitigation = re-dispatching the
  laggard's shard (simulated single-process: the shard is recomputed by the
  survivor pool).
* **fail-stop + restart** — a dead worker (no beat within ``dead_timeout``)
  triggers restore-from-latest-checkpoint; the deterministic pipeline
  regenerates the exact batch sequence, so recovery is bit-exact.
* **elastic re-mesh** — when the fleet shrinks/grows, ``plan_elastic_mesh``
  picks the largest (data × model) grid that divides the survivors and whose
  model axis still divides the arch's TP-sharded dims; training resumes on
  the new mesh from the checkpoint.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Tuple

from ..core.semaphore import Heartbeat

__all__ = ["FaultPolicy", "FleetMonitor", "plan_elastic_mesh"]


@dataclasses.dataclass
class FaultPolicy:
    straggler_factor: float = 3.0
    dead_timeout_s: float = 30.0
    max_restarts: int = 16


class FleetMonitor:
    """Tracks per-worker liveness from step completions."""

    def __init__(self, n_workers: int, policy: Optional[FaultPolicy] = None
                 ) -> None:
        self.policy = policy or FaultPolicy()
        self.hb = Heartbeat(n_workers, self.policy.straggler_factor)
        self.n_workers = n_workers
        self.restarts = 0
        self.events: List[Dict] = []

    def step_completed(self, worker: int, t: Optional[float] = None) -> None:
        self.hb.beat(worker, t)

    def check(self, now: Optional[float] = None
              ) -> Tuple[List[int], List[int]]:
        """(stragglers, dead) at time ``now``."""
        now = time.perf_counter() if now is None else now
        dead = self.hb.dead(self.policy.dead_timeout_s, now)
        stragglers = [w for w in self.hb.stragglers(now) if w not in dead]
        if stragglers:
            self.events.append({"t": now, "stragglers": stragglers})
        if dead:
            self.events.append({"t": now, "dead": dead})
        return stragglers, dead

    def should_restart(self, dead: List[int]) -> bool:
        if not dead:
            return False
        self.restarts += 1
        if self.restarts > self.policy.max_restarts:
            raise RuntimeError("restart budget exhausted")
        return True


def plan_elastic_mesh(n_devices: int, model_dims: List[int],
                      prefer_model: int = 16) -> Tuple[int, int]:
    """Largest (data, model) grid for a shrunken/grown fleet.

    ``model_dims`` are the tensor dims that must stay divisible by the model
    axis (e.g. d_ff, padded heads, padded vocab).  Preference order: keep the
    model axis as close to ``prefer_model`` as possible, then maximize total
    devices used.
    """
    best: Optional[Tuple[int, int]] = None
    best_score = (-1, -1)
    for model in range(min(prefer_model, n_devices), 0, -1):
        if any(d % model for d in model_dims if d):
            continue
        data = n_devices // model
        if data == 0:
            continue
        used = data * model
        score = (used, -abs(model - prefer_model))
        if score > best_score:
            best_score = score
            best = (data, model)
    if best is None:
        best = (n_devices, 1)
    return best
