from .trainer import Trainer
from .scheduler import AdmissionQueue, RequestTicket
from .server import ContinuousBatchingServer, Request, Server

__all__ = ["Trainer", "Server", "Request", "ContinuousBatchingServer",
           "AdmissionQueue", "RequestTicket"]
