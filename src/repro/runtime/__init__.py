from .trainer import Trainer
from .server import Request, Server

__all__ = ["Trainer", "Server", "Request"]
