"""Request scheduling for the continuous-batching server.

The decode loop is the paper's pathological small-submission regime; what a
production engine adds around it is *membership churn*: requests arrive on
their own clock (a traffic thread), wait in a bounded admission queue, get a
KV slot when one frees up, and leave (or are evicted) mid-stream while the
rest of the batch keeps decoding.  This module holds the bookkeeping side of
that — tickets, the admission queue, eviction policies, and the percentile
helpers the load harness reports with — with no JAX dependency, so it is
unit-testable without compiling anything.

Lifecycle of a :class:`RequestTicket`::

    queued --admit--> active --finish--> done
       |                 |
       | (queue full,    | (KV budget would overrun max_seq)
       |  drop_oldest)   v
       +--------------> evicted
       | (queue full, reject / prompt too long)
       v
    rejected
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import collections

__all__ = ["RequestTicket", "AdmissionQueue", "SchedulerPolicy",
           "FifoPolicy", "PriorityPolicy", "FairSharePolicy", "make_policy",
           "SCHED_POLICIES", "percentile", "latency_stats"]

#: terminal ticket states
FINISHED = ("done", "evicted", "rejected")


@dataclasses.dataclass
class RequestTicket:
    """One request's journey through the engine, with timing for metrics.

    Timestamps are ``perf_counter`` readings; ``-1.0`` means "never
    happened".  ``cap`` is the KV-capacity token budget computed at admission
    (``max_seq - len(prompt) + 1``): a request asking for more is truncated
    there and finishes as ``evicted``.
    """

    request: Any                     # runtime.server.Request
    status: str = "queued"           # queued|active|done|evicted|rejected
    reason: str = ""                 # why evicted/rejected
    slot: int = -1
    cap: int = 0
    t_submit: float = -1.0
    t_admit: float = -1.0
    t_first: float = -1.0            # first token harvested
    t_done: float = -1.0
    tokens: List[int] = dataclasses.field(default_factory=list)
    n_launches: int = 0              # decode launches this request rode
    n_prefill_launches: int = 0      # prefill/extend launches (chunks)

    @property
    def uid(self) -> int:
        return self.request.uid

    @property
    def finished(self) -> bool:
        return self.status in FINISHED

    @property
    def latency_s(self) -> float:
        """Submit -> terminal state (includes queue wait)."""
        if self.t_done < 0 or self.t_submit < 0:
            return -1.0
        return self.t_done - self.t_submit

    @property
    def ttft_s(self) -> float:
        """Submit -> first harvested token."""
        if self.t_first < 0 or self.t_submit < 0:
            return -1.0
        return self.t_first - self.t_submit

    def to_dict(self) -> Dict[str, Any]:
        return {
            "uid": self.uid, "status": self.status, "reason": self.reason,
            "prompt_len": int(len(self.request.prompt)),
            "max_new_tokens": int(self.request.max_new_tokens),
            "n_tokens": len(self.tokens),
            "n_launches": self.n_launches,
            "n_prefill_launches": self.n_prefill_launches,
            "latency_s": self.latency_s, "ttft_s": self.ttft_s,
        }


class SchedulerPolicy:
    """Chooses which queued ticket is admitted next.

    ``select`` receives a snapshot of the queued tickets (FIFO order) and
    returns the index to admit.  ``note_admitted`` is called with the ticket
    actually removed, so stateful policies (fair-share) can account for it.
    Policies never mutate the queue — :meth:`AdmissionQueue.pop` does the
    removal under its own lock.
    """

    name = "fifo"

    def select(self, queued: Sequence["RequestTicket"]) -> int:
        return 0

    def note_admitted(self, ticket: "RequestTicket") -> None:
        pass

    def note_finished(self, ticket: "RequestTicket") -> None:
        """Called when an admitted ticket reaches a terminal state, so
        stateful policies can reconcile admission-time estimates against
        what the request actually consumed.  No-op for tickets that never
        passed through :meth:`note_admitted`."""
        pass


class FifoPolicy(SchedulerPolicy):
    """Strict arrival order — the pre-policy behavior."""

    name = "fifo"


class PriorityPolicy(SchedulerPolicy):
    """Highest ``Request.priority`` first; FIFO among equals."""

    name = "priority"

    def select(self, queued: Sequence["RequestTicket"]) -> int:
        best, best_p = 0, None
        for i, t in enumerate(queued):
            p = int(getattr(t.request, "priority", 0))
            if best_p is None or p > best_p:
                best, best_p = i, p
        return best


class FairSharePolicy(SchedulerPolicy):
    """Least-served ``Request.user`` first; FIFO within a user.

    "Served" is charged as the decode-token *budget* at admission (so
    fairness reacts before any token is generated), then reconciled to
    the tokens actually emitted when the request finishes — a request
    evicted after a few tokens does not permanently bill its user for
    output it never received.  The per-user ledger is bounded: past
    ``max_users`` distinct users, the least-recently-active entry with no
    in-flight request is evicted, so long-running servers with churny
    user strings do not grow state without bound.
    """

    name = "fair"

    def __init__(self, max_users: int = 1024) -> None:
        self.max_users = int(max_users)
        # user -> tokens served, ordered by last activity (LRU eviction)
        self._served: "collections.OrderedDict[str, int]" = \
            collections.OrderedDict()
        self._inflight: Dict[int, Tuple[str, int]] = {}  # uid->(user,charge)

    @staticmethod
    def _user(request: Any) -> str:
        return str(getattr(request, "user", ""))

    def select(self, queued: Sequence["RequestTicket"]) -> int:
        best, best_cost = 0, None
        for i, t in enumerate(queued):
            cost = self._served.get(self._user(t.request), 0)
            if best_cost is None or cost < best_cost:
                best, best_cost = i, cost
        return best

    def _charge(self, user: str, amount: int) -> None:
        self._served[user] = self._served.get(user, 0) + amount
        self._served.move_to_end(user)
        while len(self._served) > self.max_users:
            live = {u for u, _ in self._inflight.values()}
            stale = next((u for u in self._served
                          if u != user and u not in live), None)
            if stale is None:
                break
            del self._served[stale]

    def note_admitted(self, ticket: "RequestTicket") -> None:
        user = self._user(ticket.request)
        est = int(getattr(ticket.request, "max_new_tokens", 1))
        self._inflight[ticket.uid] = (user, est)
        self._charge(user, est)

    def note_finished(self, ticket: "RequestTicket") -> None:
        entry = self._inflight.pop(ticket.uid, None)
        if entry is None:
            return
        user, est = entry
        self._charge(user, len(ticket.tokens) - est)


SCHED_POLICIES = ("fifo", "priority", "fair")


def make_policy(name: str) -> SchedulerPolicy:
    if name == "fifo":
        return FifoPolicy()
    if name == "priority":
        return PriorityPolicy()
    if name == "fair":
        return FairSharePolicy()
    raise ValueError(f"unknown scheduler policy {name!r}; "
                     f"expected one of {SCHED_POLICIES}")


class AdmissionQueue:
    """Bounded, thread-safe FIFO of queued tickets.

    ``policy`` decides what happens when the queue is full:

    * ``"reject"`` — the *incoming* ticket is refused (callers mark it
      ``rejected``); the queue is untouched.
    * ``"drop_oldest"`` — the oldest *queued* ticket is evicted to make
      room (callers mark it ``evicted``); the incoming one is accepted.

    ``close()`` marks end-of-intake: further submits are refused and the
    engine's drain loop knows no more work is coming.
    """

    POLICIES = ("reject", "drop_oldest")

    def __init__(self, max_pending: int = 256,
                 policy: str = "reject") -> None:
        if policy not in self.POLICIES:
            raise ValueError(f"unknown admission policy {policy!r}; "
                             f"expected one of {self.POLICIES}")
        self.max_pending = int(max_pending)
        self.policy = policy
        self._q: Deque[RequestTicket] = collections.deque()
        self._lock = threading.Lock()
        # wakes the engine's drain loop on submit/close so run() blocks on
        # this instead of spinning on poll_s (which stays as the fallback)
        self._cv = threading.Condition(self._lock)
        self._closed = False
        self.n_submitted = 0
        self.n_refused = 0
        self.n_dropped = 0

    def submit(self, ticket: RequestTicket
               ) -> Tuple[bool, Optional[RequestTicket]]:
        """Try to enqueue; returns ``(accepted, dropped_ticket)``.

        ``dropped_ticket`` is the queued ticket evicted under
        ``drop_oldest`` (None otherwise).  The caller owns status updates
        for both tickets — the queue only moves them.
        """
        with self._lock:
            if self._closed:
                self.n_refused += 1
                return False, None
            dropped = None
            if len(self._q) >= self.max_pending:
                if self.policy == "reject":
                    self.n_refused += 1
                    return False, None
                dropped = self._q.popleft()
                self.n_dropped += 1
            self._q.append(ticket)
            self.n_submitted += 1
            self._cv.notify_all()
            return True, dropped

    def pop(self, policy: Optional["SchedulerPolicy"] = None
            ) -> Optional[RequestTicket]:
        """Remove and return the next ticket per ``policy`` (default FIFO).

        The policy sees an immutable snapshot and returns an index; removal
        happens here, under the queue lock, so policies can reorder without
        reaching into ``_q`` (and ``drop_oldest`` semantics in
        :meth:`submit` are untouched — overflow always drops the *oldest*
        queued ticket regardless of admission order).
        """
        with self._lock:
            if not self._q:
                return None
            i = 0
            if policy is not None:
                i = int(policy.select(tuple(self._q)))
                if not 0 <= i < len(self._q):
                    i = 0
            t = self._q[i]
            del self._q[i]
        if policy is not None:
            policy.note_admitted(t)
        return t

    def peek(self, policy: Optional["SchedulerPolicy"] = None
             ) -> Optional[RequestTicket]:
        """The ticket :meth:`pop` would return, without removing it."""
        with self._lock:
            if not self._q:
                return None
            i = 0
            if policy is not None:
                i = int(policy.select(tuple(self._q)))
                if not 0 <= i < len(self._q):
                    i = 0
            return self._q[i]

    def wait_for_work(self, timeout: float) -> bool:
        """Block until a ticket is queued or intake closes (or timeout).

        Returns True if there is something to look at.  This is what lets
        the engine's drain loop sleep instead of busy-polling.
        """
        with self._lock:
            if self._q or self._closed:
                return True
            self._cv.wait(timeout=max(0.0, timeout))
            return bool(self._q) or self._closed

    def close(self) -> None:
        with self._lock:
            self._closed = True
            self._cv.notify_all()

    @property
    def closed(self) -> bool:
        with self._lock:
            return self._closed

    def __len__(self) -> int:
        with self._lock:
            return len(self._q)


def percentile(xs: Sequence[float], p: float) -> float:
    """Linear-interpolated percentile (numpy-free so it runs anywhere)."""
    vals = sorted(x for x in xs if x >= 0.0)
    if not vals:
        return 0.0
    if len(vals) == 1:
        return float(vals[0])
    rank = (p / 100.0) * (len(vals) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(vals) - 1)
    frac = rank - lo
    return float(vals[lo] * (1.0 - frac) + vals[hi] * frac)


def latency_stats(tickets: Sequence[RequestTicket]) -> Dict[str, float]:
    """p50/p99 latency and time-to-first-token over terminal tickets."""
    lats = [t.latency_s for t in tickets if t.t_done >= 0]
    ttfts = [t.ttft_s for t in tickets if t.t_first >= 0]
    return {
        "latency_p50_s": percentile(lats, 50.0),
        "latency_p99_s": percentile(lats, 99.0),
        "ttft_p50_s": percentile(ttfts, 50.0),
        "ttft_p99_s": percentile(ttfts, 99.0),
    }
