"""Request scheduling for the continuous-batching server.

The decode loop is the paper's pathological small-submission regime; what a
production engine adds around it is *membership churn*: requests arrive on
their own clock (a traffic thread), wait in a bounded admission queue, get a
KV slot when one frees up, and leave (or are evicted) mid-stream while the
rest of the batch keeps decoding.  This module holds the bookkeeping side of
that — tickets, the admission queue, eviction policies, and the percentile
helpers the load harness reports with — with no JAX dependency, so it is
unit-testable without compiling anything.

Lifecycle of a :class:`RequestTicket`::

    queued --admit--> active --finish--> done
       |                 |
       | (queue full,    | (KV budget would overrun max_seq)
       |  drop_oldest)   v
       +--------------> evicted
       | (queue full, reject / prompt too long)
       v
    rejected
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Deque, Dict, List, Optional, Sequence, Tuple

import collections

__all__ = ["RequestTicket", "AdmissionQueue", "percentile", "latency_stats"]

#: terminal ticket states
FINISHED = ("done", "evicted", "rejected")


@dataclasses.dataclass
class RequestTicket:
    """One request's journey through the engine, with timing for metrics.

    Timestamps are ``perf_counter`` readings; ``-1.0`` means "never
    happened".  ``cap`` is the KV-capacity token budget computed at admission
    (``max_seq - len(prompt) + 1``): a request asking for more is truncated
    there and finishes as ``evicted``.
    """

    request: Any                     # runtime.server.Request
    status: str = "queued"           # queued|active|done|evicted|rejected
    reason: str = ""                 # why evicted/rejected
    slot: int = -1
    cap: int = 0
    t_submit: float = -1.0
    t_admit: float = -1.0
    t_first: float = -1.0            # first token harvested
    t_done: float = -1.0
    tokens: List[int] = dataclasses.field(default_factory=list)
    n_launches: int = 0              # decode launches this request rode

    @property
    def uid(self) -> int:
        return self.request.uid

    @property
    def finished(self) -> bool:
        return self.status in FINISHED

    @property
    def latency_s(self) -> float:
        """Submit -> terminal state (includes queue wait)."""
        if self.t_done < 0 or self.t_submit < 0:
            return -1.0
        return self.t_done - self.t_submit

    @property
    def ttft_s(self) -> float:
        """Submit -> first harvested token."""
        if self.t_first < 0 or self.t_submit < 0:
            return -1.0
        return self.t_first - self.t_submit

    def to_dict(self) -> Dict[str, Any]:
        return {
            "uid": self.uid, "status": self.status, "reason": self.reason,
            "prompt_len": int(len(self.request.prompt)),
            "max_new_tokens": int(self.request.max_new_tokens),
            "n_tokens": len(self.tokens),
            "n_launches": self.n_launches,
            "latency_s": self.latency_s, "ttft_s": self.ttft_s,
        }


class AdmissionQueue:
    """Bounded, thread-safe FIFO of queued tickets.

    ``policy`` decides what happens when the queue is full:

    * ``"reject"`` — the *incoming* ticket is refused (callers mark it
      ``rejected``); the queue is untouched.
    * ``"drop_oldest"`` — the oldest *queued* ticket is evicted to make
      room (callers mark it ``evicted``); the incoming one is accepted.

    ``close()`` marks end-of-intake: further submits are refused and the
    engine's drain loop knows no more work is coming.
    """

    POLICIES = ("reject", "drop_oldest")

    def __init__(self, max_pending: int = 256,
                 policy: str = "reject") -> None:
        if policy not in self.POLICIES:
            raise ValueError(f"unknown admission policy {policy!r}; "
                             f"expected one of {self.POLICIES}")
        self.max_pending = int(max_pending)
        self.policy = policy
        self._q: Deque[RequestTicket] = collections.deque()
        self._lock = threading.Lock()
        self._closed = False
        self.n_submitted = 0
        self.n_refused = 0
        self.n_dropped = 0

    def submit(self, ticket: RequestTicket
               ) -> Tuple[bool, Optional[RequestTicket]]:
        """Try to enqueue; returns ``(accepted, dropped_ticket)``.

        ``dropped_ticket`` is the queued ticket evicted under
        ``drop_oldest`` (None otherwise).  The caller owns status updates
        for both tickets — the queue only moves them.
        """
        with self._lock:
            if self._closed:
                self.n_refused += 1
                return False, None
            dropped = None
            if len(self._q) >= self.max_pending:
                if self.policy == "reject":
                    self.n_refused += 1
                    return False, None
                dropped = self._q.popleft()
                self.n_dropped += 1
            self._q.append(ticket)
            self.n_submitted += 1
            return True, dropped

    def pop(self) -> Optional[RequestTicket]:
        with self._lock:
            return self._q.popleft() if self._q else None

    def close(self) -> None:
        with self._lock:
            self._closed = True

    @property
    def closed(self) -> bool:
        with self._lock:
            return self._closed

    def __len__(self) -> int:
        with self._lock:
            return len(self._q)


def percentile(xs: Sequence[float], p: float) -> float:
    """Linear-interpolated percentile (numpy-free so it runs anywhere)."""
    vals = sorted(x for x in xs if x >= 0.0)
    if not vals:
        return 0.0
    if len(vals) == 1:
        return float(vals[0])
    rank = (p / 100.0) * (len(vals) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(vals) - 1)
    frac = rank - lo
    return float(vals[lo] * (1.0 - frac) + vals[hi] * frac)


def latency_stats(tickets: Sequence[RequestTicket]) -> Dict[str, float]:
    """p50/p99 latency and time-to-first-token over terminal tickets."""
    lats = [t.latency_s for t in tickets if t.t_done >= 0]
    ttfts = [t.ttft_s for t in tickets if t.t_first >= 0]
    return {
        "latency_p50_s": percentile(lats, 50.0),
        "latency_p99_s": percentile(lats, 99.0),
        "ttft_p50_s": percentile(ttfts, 50.0),
        "ttft_p99_s": percentile(ttfts, 99.0),
    }
