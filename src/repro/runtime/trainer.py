"""Trainer: the production loop wiring every subsystem together.

Submission-aware by construction (the paper's lesson as defaults):

* **multi-step graph launch** — ``steps_per_launch`` K > 1 scans K train
  steps into ONE dispatch (one "doorbell" submits K steps, O(1) command
  footprint; see core/graphs.py).  Host involvement in the critical path
  drops by K×, the CUDA-13.0-and-beyond end point of the paper's §6.3.
* **doorbell accounting** — every dispatch is recorded by a DoorbellTracker;
  ``submission_report()`` is the per-run Listing-1 analogue.
* **unified trace session** — one :class:`~repro.core.session.TraceSession`
  drives all instrumentation (dispatch, progress, compile); pass ``session=``
  to share a timeline with a Server/benchmark, or read ``trainer.session``.
* **async checkpoints, deterministic data, heartbeat fault monitor.**
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..configs.shapes import ShapeConfig
from ..core.session import TraceSession
from ..data.pipeline import make_pipeline
from ..models import get_model
from ..optim.adamw import adamw_init
from ..optim.compression import ef_init
from .checkpoint import CheckpointManager, latest_step, restore
from .fault_tolerance import FleetMonitor
from .steps import init_all, make_train_step

__all__ = ["Trainer"]


class Trainer:
    def __init__(self, cfg: ModelConfig, shape: ShapeConfig,
                 mesh: Optional[Any] = None,
                 steps_per_launch: Optional[int] = None,
                 ckpt_dir: Optional[str] = None,
                 ckpt_every: int = 100,
                 grad_compression: Optional[str] = None,
                 peak_lr: float = 3e-4,
                 seed: int = 0,
                 session: Optional[TraceSession] = None) -> None:
        self.cfg = cfg
        self.shape = shape
        self.mesh = mesh
        # ``steps_per_launch=None`` -> auto-apply the tuned policy for this
        # (model config, platform, device count); explicit values win.
        self.policy = None
        if steps_per_launch is None:
            from ..tune.policy import load_policy_for
            self.policy = load_policy_for(cfg)
            steps_per_launch = (self.policy.knob("steps_per_launch", 1)
                                if self.policy else 1)
        self.k = max(1, int(steps_per_launch))
        self.model = get_model(cfg)
        # One session carries every event this trainer emits (dispatch,
        # progress, compile); callers share theirs to merge timelines.
        self.session = session or TraceSession(name="trainer")
        self.tracker = self.session.doorbell
        self.progress = self.session.progress
        self.monitor = FleetMonitor(n_workers=1)
        self.grad_compression = grad_compression
        self.ckpt = (CheckpointManager(ckpt_dir, every_steps=ckpt_every)
                     if ckpt_dir else None)
        self.seed = seed
        self.step = 0
        self.metrics_log: list = []

        key = jax.random.PRNGKey(seed)
        with self.session:          # make init_all's ambient_span land here
            self.params, self.opt_state = init_all(self.model, cfg, key)
        self.ef_state = (ef_init(self.params)
                         if grad_compression == "int8" else None)

        step_fn = make_train_step(self.model, cfg, peak_lr=peak_lr,
                                  grad_compression=grad_compression)
        self._step_fn = step_fn

        if self.k == 1:
            if self.ef_state is not None:
                fn = lambda p, o, b, e: step_fn(p, o, b, e)
            else:
                fn = lambda p, o, b: step_fn(p, o, b)
            self._jitted = self.tracker.wrap(jax.jit(fn), "train_step")
        else:
            # multi-step graph launch: one dispatch = K steps
            def k_steps(params, opt_state, batches):
                def body(carry, batch):
                    p, o = carry
                    p, o, m = step_fn(p, o, batch)
                    return (p, o), m
                (params, opt_state), ms = jax.lax.scan(
                    body, (params, opt_state), batches)
                return params, opt_state, ms

            self._jitted = self.tracker.wrap(jax.jit(k_steps),
                                             "train_k_steps")

    # ------------------------------------------------------------------
    def maybe_restore(self) -> bool:
        if self.ckpt is None or latest_step(self.ckpt.dir) is None:
            return False
        (self.params, self.opt_state), step, extra = restore(
            self.ckpt.dir, (self.params, self.opt_state))
        self.step = int(extra.get("next_step", step))
        return True

    def _stack_batches(self, pipe, n: int):
        batches = []
        for _ in range(n):
            _, b = pipe.next()
            batches.append(b)
        return {k: np.stack([b[k] for b in batches])
                for k in batches[0]}

    def train(self, num_steps: int, pipe=None) -> Dict[str, Any]:
        own_pipe = pipe is None
        if own_pipe:
            pipe = make_pipeline(self.cfg, self.shape, self.seed,
                                 start_step=self.step)
        t0 = time.perf_counter()
        # session may be shared with other consumers: report per-run deltas
        db0 = self.tracker.count
        ev0 = self.session.n_events
        try:
            while self.step < num_steps:
                # one span per optimiser iteration — covers data fetch, the
                # (possibly K-step) launch, and the progress fence, so span
                # attribution answers "what does one train step cost"
                with self.session.span("train.step", step=self.step,
                                       k=self.k):
                    if self.k == 1:
                        _, batch = pipe.next()
                        if self.ef_state is not None:
                            (self.params, self.opt_state, metrics,
                             self.ef_state) = self._jitted(
                                self.params, self.opt_state, batch,
                                self.ef_state)
                        else:
                            (self.params, self.opt_state,
                             metrics) = self._jitted(
                                self.params, self.opt_state, batch)
                        self.step += 1
                    else:
                        batches = self._stack_batches(pipe, self.k)
                        self.params, self.opt_state, metrics = self._jitted(
                            self.params, self.opt_state, batches)
                        self.step += self.k
                    tok = self.progress.release(metrics["loss"])
                    self.progress.wait(tok)                # fence the launch
                self.monitor.step_completed(0)
                loss = float(jnp.ravel(metrics["loss"])[-1])
                self.metrics_log.append({"step": self.step, "loss": loss})
                if self.ckpt is not None:
                    self.ckpt.maybe_save(
                        self.step, (self.params, self.opt_state),
                        extra={"next_step": self.step})
        finally:
            if own_pipe:
                pipe.stop()
            if self.ckpt is not None:
                self.ckpt.wait()
        wall = time.perf_counter() - t0
        doorbells = self.tracker.count - db0
        return {"steps": self.step, "wall_s": wall,
                "final_loss": self.metrics_log[-1]["loss"],
                "doorbells": doorbells,
                "steps_per_doorbell": self.step / max(1, doorbells),
                "trace_events": self.session.n_events - ev0}

    def submission_report(self) -> Dict[str, Any]:
        out = self.tracker.summary()
        out["session"] = self.session.summary()
        return out

    def trace_report(self, max_events: int = 60) -> str:
        """Listing-1-style interleaved timeline for this trainer's run."""
        return self.session.report(max_events=max_events)
