"""KV-cache management for the continuous-batching engine.

The serve engine's scale bottleneck is its memory path: PR 7 gave every
slot a full ``max_seq`` dense cache and prefilled whole prompts in one
launch, stalling the decode loop.  This module extracts that state handling
behind :class:`KVCacheManager` with two backends:

* :class:`DenseKV` — the original layout, verbatim: one stacked batch-1
  decode-state pytree per slot, whole-prompt prefill, one vmapped
  ``decode_slots`` launch.  The refactor is token-bit-identical to the
  pre-refactor engine (same jitted functions, same launch order).
* :class:`PagedKV` — a single global page pool ``[L, pages, page_tokens,
  Hkv, hd]`` with per-slot block tables.  A decode launch gathers each
  slot's pages into the *same contiguous layout the dense path decodes*,
  runs the identical decode math, and scatters the new rows back — so
  paged tokens are bit-identical to dense.  Pages holding a fully-prefilled
  prompt prefix are content-addressed (hash chain over page tokens) and
  shared across requests with the same prefix: a prefix hit skips those
  prefill tokens entirely, which is what makes the per-request command
  footprint (prefill doorbells, DMA payload bytes) sublinear in
  shared-prefix traffic.  Pool exhaustion evicts with ``reason="kv_pages"``
  (the dense ``kv_overrun`` cap semantics are preserved by the engine in
  both backends).

Both backends support **chunked prefill**: prompts longer than
``prefill_chunk`` are advanced one bounded ``prefill_extend`` launch at a
time (``serve.prefill_chunk`` spans), interleaved by the engine with decode
iterations so long prompts no longer stall active slots.  Chunked prefill
is bit-identical to whole-prompt prefill (masked-out future cache positions
contribute exactly-zero softmax weight; see ``models.attention``).

Page 0 of the pool is a reserved scratch page: free or still-prefilling
slots point every block-table row at it with length 0, so the vmapped
decode launch stays total and shape-stable — exactly the property the dense
backend gets from keeping a well-formed state in every slot.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Any, Dict, List, Optional, TYPE_CHECKING

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.shapes import kv_geometry
from ..models.attention import gather_block_table, scatter_block_rows

if TYPE_CHECKING:                                    # pragma: no cover
    from .scheduler import RequestTicket
    from .server import Server

__all__ = ["KVCacheManager", "DenseKV", "PagedKV", "make_kv", "KV_BACKENDS"]

KV_BACKENDS = ("dense", "paged")


def _decode_slot_fn(model, T: int):
    """The shared per-slot decode body: scan T greedy steps, one launch.

    Both backends jit/vmap this exact function, which is what makes their
    token streams bit-identical — the only difference is where the cache
    lives before and after.
    """

    def decode_slot(params, state, tok):             # state: batch-1 pytree
        def body(carry, _):
            st, t = carry
            st, logits = model.decode_step(params, st, t)
            nxt = jnp.argmax(logits[:, -1:, :], axis=-1).astype(t.dtype)
            return (st, nxt), nxt[0, 0]
        (state, nxt), toks = jax.lax.scan(
            body, (state, tok), None, length=T)
        return state, toks, nxt                      # [T], [1, 1]

    return decode_slot


class KVCacheManager:
    """Backend interface the engine schedules against.

    Lifecycle per request: :meth:`begin` claims resources for a slot
    (returns False only on unrecoverable page exhaustion -> the engine
    evicts with ``reason="kv_pages"``); :meth:`prefill_step` advances the
    slot's prefill by at most one launch and returns the first generated
    token once the prompt is fully in cache; :meth:`reserve_decode` grows
    per-slot capacity ahead of a decode launch (returning victims when the
    pool cannot); :meth:`decode` runs the one vmapped launch over all
    slots; :meth:`release` returns the slot's memory.
    """

    name = "none"
    chunk = 0                    # prefill_chunk knob (0 = whole-prompt)

    def begin(self, slot: int, tix: "RequestTicket") -> bool:
        raise NotImplementedError

    def prefill_step(self, slot: int) -> Optional[int]:
        raise NotImplementedError

    def reserve_decode(self, slots: List[int]) -> List[int]:
        return []

    def decode(self) -> np.ndarray:
        raise NotImplementedError

    def release(self, slot: int) -> None:
        pass

    def stats(self) -> Dict[str, Any]:
        raise NotImplementedError


class _PrefillCounters:
    """Shared launch/byte accounting (feeds loadtest --json and BENCH)."""

    def __init__(self) -> None:
        self.prefill_launches = 0        # all prefill/extend launches
        self.prefill_chunk_launches = 0  # the subset that were chunk ticks
        self.prefill_tokens = 0          # prompt tokens actually pushed
        self.chunked_prompts = 0         # prompts that needed >1 launch

    @property
    def prefill_payload_bytes(self) -> int:
        return 4 * self.prefill_tokens

    def to_dict(self) -> Dict[str, int]:
        return {
            "prefill_launches": self.prefill_launches,
            "prefill_chunk_launches": self.prefill_chunk_launches,
            "prefill_tokens": self.prefill_tokens,
            "prefill_payload_bytes": self.prefill_payload_bytes,
            "chunked_prompts": self.chunked_prompts,
        }


def _require_extend(model, why: str) -> None:
    if not hasattr(model, "prefill_extend"):
        raise ValueError(
            f"{why} requires a model with a prefill_extend() decode-state "
            f"extension (transformer-family); {type(model).__name__} has "
            f"none — use kv='dense' with prefill_chunk=0")


class DenseKV(KVCacheManager):
    """Today's layout behind the manager interface (bit-identical refactor).

    Slot state, install scatter, and the vmapped ``decode_slots`` launch are
    the exact jitted functions the engine built inline before the refactor.
    Chunked prefill stages chunks in a private batch-1 state and installs it
    on completion, so the stacked slot states see exactly one update per
    admission either way.
    """

    name = "dense"

    def __init__(self, engine: "Server", prefill_chunk: int = 0) -> None:
        self.engine = engine
        self.chunk = max(0, int(prefill_chunk))
        if self.chunk:
            _require_extend(engine.model, "chunked prefill")
        one = engine.model.init_decode_state(1, engine.max_seq)
        self._slots = jax.tree_util.tree_map(
            lambda x: jnp.stack([x] * engine.B), one)
        self._nxt = jnp.zeros((engine.B, 1, 1), jnp.int32)
        self._decode_slots = engine.tracker.wrap(
            jax.jit(jax.vmap(_decode_slot_fn(engine.model, engine.T),
                             in_axes=(None, 0, 0))),
            "decode_slots")
        # scatter one admitted request's prefilled state into its slot
        self._install = jax.jit(
            lambda full, part, i: jax.tree_util.tree_map(
                lambda f, o: jax.lax.dynamic_update_index_in_dim(f, o, i, 0),
                full, part))
        self._extend = None
        if self.chunk:
            self._extend = engine.tracker.wrap(
                jax.jit(engine.model.prefill_extend), "prefill_extend")
        self._pending: Dict[int, Dict[str, Any]] = {}
        self.counters = _PrefillCounters()

    # -- prefill -----------------------------------------------------------
    def begin(self, slot: int, tix: "RequestTicket") -> bool:
        prompt = np.asarray(tix.request.prompt, np.int32)
        chunked = bool(self.chunk) and len(prompt) > self.chunk
        self._pending[slot] = {"tix": tix, "prompt": prompt, "pos": 0,
                               "chunked": chunked, "state": None}
        if chunked:
            self.counters.chunked_prompts += 1
        return True

    def prefill_step(self, slot: int) -> Optional[int]:
        p = self._pending[slot]
        tix, prompt = p["tix"], p["prompt"]
        eng = self.engine
        if not p["chunked"]:
            with eng.session.span("serve.prefill", uid=tix.uid,
                                  prompt_len=int(len(prompt))):
                state, logits = eng._prefill(eng.params, jnp.asarray(
                    prompt[None, :]))
            self.counters.prefill_launches += 1
            self.counters.prefill_tokens += len(prompt)
            tix.n_prefill_launches += 1
            return self._complete(slot, state, logits)
        if p["state"] is None:
            p["state"] = eng.model.init_decode_state(1, eng.max_seq)
        pos = p["pos"]
        c = min(self.chunk, len(prompt) - pos)
        with eng.session.span("serve.prefill_chunk", uid=tix.uid, start=pos,
                              size=c, prompt_len=int(len(prompt))):
            p["state"], logits = self._extend(
                eng.params, p["state"], jnp.asarray(prompt[None, pos:pos + c]))
        p["pos"] = pos + c
        self.counters.prefill_launches += 1
        self.counters.prefill_chunk_launches += 1
        self.counters.prefill_tokens += c
        tix.n_prefill_launches += 1
        if p["pos"] < len(prompt):
            return None
        return self._complete(slot, p["state"], logits)

    def _complete(self, slot: int, state, logits) -> int:
        tok0 = int(jnp.argmax(logits[0, -1, :]))
        self._slots = self._install(self._slots, state, np.int32(slot))
        self._nxt = self._nxt.at[slot, 0, 0].set(tok0)
        del self._pending[slot]
        return tok0

    # -- decode ------------------------------------------------------------
    def decode(self) -> np.ndarray:
        self._slots, toks, self._nxt = self._decode_slots(
            self.engine.params, self._slots, self._nxt)
        return np.asarray(toks)                      # [B, T] host sync

    def release(self, slot: int) -> None:
        self._pending.pop(slot, None)

    def stats(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"backend": self.name,
                               "prefill_chunk": self.chunk}
        out.update(self.counters.to_dict())
        return out


def _hash_page(parent: bytes, tokens: np.ndarray) -> bytes:
    h = hashlib.blake2b(digest_size=16)
    h.update(parent)
    h.update(np.ascontiguousarray(tokens, np.int32).tobytes())
    return h.digest()


class PagedKV(KVCacheManager):
    """Fixed-size pages + block tables + shared-prefix page reuse.

    Geometry: pool ``[L, 1 + pages, page_tokens, Hkv, hd]`` (page 0 is
    scratch), block tables ``[B, max_seq // page_tokens]`` host-side.  Pages
    whose span lies entirely inside the *prompt* (decode never writes them:
    the first decode write lands at position ``prompt_len``) are registered
    under a content hash chain once prefilled, and later requests sharing
    that prefix attach to them instead of re-prefilling — always leaving at
    least the final prompt token to prefill so the first output token's
    logits exist.

    Allocation: free list first, then reclaim of the least-recently-freed
    cached (refcount-0 but registered) page.  When both are empty the
    requester loses: ``begin`` returns False / ``reserve_decode`` reports
    the slot as a victim, and the engine evicts it with
    ``reason="kv_pages"``.
    """

    name = "paged"

    def __init__(self, engine: "Server", page_tokens: int = 16,
                 pages: Optional[int] = None,
                 prefill_chunk: int = 0) -> None:
        _require_extend(engine.model, "kv='paged'")
        self.engine = engine
        self.pt = int(page_tokens)
        self.chunk = max(0, int(prefill_chunk))
        self.n_blk, default_pages = kv_geometry(
            engine.max_seq, self.pt, engine.B)
        self.pages = int(pages) if pages is not None else default_pages
        if self.pages < self.n_blk:
            raise ValueError(
                f"kv_pages={self.pages} cannot hold even one full slot "
                f"({self.n_blk} pages of {self.pt} tokens)")
        cfg, model = engine.cfg, engine.model
        from ..models.layers import dtype_of
        P = 1 + self.pages                            # + scratch page 0
        shape = (cfg.n_layers, P, self.pt, cfg.n_kv_heads, cfg.hd)
        self.k_pool = jnp.zeros(shape, dtype_of(cfg))
        self.v_pool = jnp.zeros(shape, dtype_of(cfg))
        self.page_bytes = int(2 * np.prod(shape[2:]) * cfg.n_layers
                              * self.k_pool.dtype.itemsize)

        B = engine.B
        self.tables = np.zeros((B, self.n_blk), np.int32)   # 0 = scratch
        self.n_rows = np.zeros(B, np.int32)           # valid table rows
        self.lengths = np.zeros(B, np.int32)          # 0 until installed
        self.ready = np.zeros(B, bool)                # prefill complete
        self._nxt = jnp.zeros((B, 1, 1), jnp.int32)

        self._free: List[int] = list(range(P - 1, 0, -1))   # pop() -> 1,2,..
        self._ref = np.zeros(P, np.int64)
        self._key_of: Dict[int, bytes] = {}           # page -> content key
        self._page_of: Dict[bytes, int] = {}          # content key -> page
        self._cached: "OrderedDict[int, None]" = OrderedDict()  # ref==0 LRU
        self._chain: Dict[int, List[bytes]] = {}      # slot -> page keys
        self._pending: Dict[int, Dict[str, Any]] = {}

        self.counters = _PrefillCounters()
        self.pages_allocated = 0                      # cumulative fresh
        self.pages_reused = 0                         # prefix-hit attaches
        self.prefix_hits = 0                          # requests that hit
        self.prefix_hit_tokens = 0
        self.pages_peak = 0

        decode_slot = _decode_slot_fn(model, engine.T)
        T, n_blk, pt = engine.T, self.n_blk, self.pt
        S = engine.max_seq
        L, Hk, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd

        def decode_paged(params, k_pool, v_pool, tables, lengths, nxt):
            def slot_fn(table, length, tok):
                st = {"k": gather_block_table(k_pool, table),
                      "v": gather_block_table(v_pool, table),
                      "length": length}
                st, toks, tok = decode_slot(params, st, tok)
                # rows written this launch; start clamps exactly like the
                # dense dynamic_update_slice when a finishing slot overruns
                start = jnp.minimum(length, S - T)
                rk = jax.lax.dynamic_slice(
                    st["k"], (0, 0, start, 0, 0), (L, 1, T, Hk, hd))[:, 0]
                rv = jax.lax.dynamic_slice(
                    st["v"], (0, 0, start, 0, 0), (L, 1, T, Hk, hd))[:, 0]
                return toks, tok, rk, rv

            toks, nxts, rows_k, rows_v = jax.vmap(slot_fn)(
                tables, lengths, nxt)

            def body(b, pools):
                kp, vp = pools
                tbl = tables[b]
                start = jnp.minimum(lengths[b], S - T)
                kp = scatter_block_rows(kp, tbl, rows_k[b], start)
                vp = scatter_block_rows(vp, tbl, rows_v[b], start)
                return kp, vp

            k_pool, v_pool = jax.lax.fori_loop(
                0, tables.shape[0], body, (k_pool, v_pool))
            return k_pool, v_pool, toks, nxts

        self._decode_slots = engine.tracker.wrap(
            jax.jit(decode_paged), "decode_slots")

        def extend_paged(params, k_pool, v_pool, table, start, tokens):
            st = {"k": gather_block_table(k_pool, table),
                  "v": gather_block_table(v_pool, table),
                  "length": start}
            st, logits = model.prefill_extend(params, st, tokens)
            C = tokens.shape[1]
            rk = jax.lax.dynamic_slice(
                st["k"], (0, 0, start, 0, 0), (L, 1, C, Hk, hd))[:, 0]
            rv = jax.lax.dynamic_slice(
                st["v"], (0, 0, start, 0, 0), (L, 1, C, Hk, hd))[:, 0]
            k_pool = scatter_block_rows(k_pool, table, rk, start)
            v_pool = scatter_block_rows(v_pool, table, rv, start)
            return k_pool, v_pool, logits

        self._extend = engine.tracker.wrap(
            jax.jit(extend_paged), "prefill_extend")

    # -- page accounting ---------------------------------------------------
    @property
    def pages_in_use(self) -> int:
        return self.pages - len(self._free) - len(self._cached)

    def _take_pages(self, n: int) -> Optional[List[int]]:
        """n fresh pages, reclaiming cached prefix pages if needed."""
        got: List[int] = []
        while len(got) < n:
            if self._free:
                got.append(self._free.pop())
            elif self._cached:
                page, _ = self._cached.popitem(last=False)   # oldest
                key = self._key_of.pop(page)
                self._page_of.pop(key, None)
                got.append(page)
            else:
                self._free.extend(got)                       # rollback
                return None
        self.pages_allocated += len(got)
        self.pages_peak = max(self.pages_peak, self.pages_in_use)
        return got

    def _drop_ref(self, page: int) -> None:
        self._ref[page] -= 1
        if self._ref[page] == 0:
            if page in self._key_of:
                self._cached[page] = None            # reusable, reclaimable
            else:
                self._free.append(page)

    def _register(self, page: int, key: bytes) -> None:
        if key not in self._page_of and page not in self._key_of:
            self._page_of[key] = page
            self._key_of[page] = key

    # -- prefill -----------------------------------------------------------
    def begin(self, slot: int, tix: "RequestTicket") -> bool:
        prompt = np.asarray(tix.request.prompt, np.int32)
        plen = len(prompt)
        chain: List[bytes] = []
        key = b"kv-root"
        for p in range(plen // self.pt):             # fully-covered pages
            key = _hash_page(key, prompt[p * self.pt:(p + 1) * self.pt])
            chain.append(key)
        # shareable prefix: page span must end before the last prompt token
        # so at least one token remains to prefill (tok0 needs logits)
        n_share_max = (plen - 1) // self.pt
        shared: List[int] = []
        for p in range(min(n_share_max, len(chain))):
            pg = self._page_of.get(chain[p])
            if pg is None:
                break
            # pin BEFORE allocating: a refcount-0 shared page sits in
            # self._cached, which _take_pages reclaims under pool pressure
            # — left unpinned, the same physical page could be handed back
            # as a fresh prefill target and the prefill would clobber the
            # shared prefix content
            if self._ref[pg] == 0:
                self._cached.pop(pg, None)
            self._ref[pg] += 1
            shared.append(pg)
        n_total = -(-plen // self.pt)                # pages covering prompt
        got = self._take_pages(n_total - len(shared))
        if got is None:
            for pg in shared:                        # unpin: roll back
                self._drop_ref(pg)
            return False
        for pg in got:
            self._ref[pg] += 1
        self.tables[slot, :n_total] = shared + got
        self.tables[slot, n_total:] = 0
        self.n_rows[slot] = n_total
        self.lengths[slot] = 0
        self.ready[slot] = False
        self._chain[slot] = chain
        start = len(shared) * self.pt
        chunked = bool(self.chunk) and (plen - start) > self.chunk
        self._pending[slot] = {"tix": tix, "prompt": prompt, "pos": start,
                               "chunked": chunked}
        if chunked:
            self.counters.chunked_prompts += 1
        if shared:
            self.prefix_hits += 1
            self.pages_reused += len(shared)
            self.prefix_hit_tokens += start
            self.engine.session.emit(
                "progress", "kv.prefix_hit", uid=tix.uid, slot=slot,
                pages=len(shared), tokens=start,
                payload_bytes=len(shared) * self.page_bytes)
        if got:
            self.engine.session.emit(
                "progress", "kv.alloc", uid=tix.uid, slot=slot,
                pages=len(got), payload_bytes=len(got) * self.page_bytes)
        return True

    def prefill_step(self, slot: int) -> Optional[int]:
        p = self._pending[slot]
        tix, prompt, pos = p["tix"], p["prompt"], p["pos"]
        eng = self.engine
        plen = len(prompt)
        remaining = plen - pos
        c = min(self.chunk, remaining) if p["chunked"] else remaining
        span_name = "serve.prefill_chunk" if p["chunked"] else "serve.prefill"
        table = jnp.asarray(self.tables[slot])
        with eng.session.span(span_name, uid=tix.uid, start=pos, size=c,
                              prompt_len=plen):
            self.k_pool, self.v_pool, logits = self._extend(
                eng.params, self.k_pool, self.v_pool, table,
                jnp.asarray(pos, jnp.int32), jnp.asarray(prompt[None,
                                                                pos:pos + c]))
        p["pos"] = pos + c
        self.counters.prefill_launches += 1
        if p["chunked"]:
            self.counters.prefill_chunk_launches += 1
        self.counters.prefill_tokens += c
        tix.n_prefill_launches += 1
        if p["pos"] < plen:
            return None
        # prompt fully in cache: register shareable pages, go decodable.
        # Pages overlapping [max_seq - T, max_seq) are excluded: a slot
        # finishing at the KV cap scatter-writes its clamped decode rows
        # there (scatter_block_rows start = min(length, S - T)), and a
        # registered page must stay immutable once other requests attach
        # to it — reachable when page_tokens < tokens_per_launch (the
        # tuner ladder offers page_tokens=4 against T=8).
        chain = self._chain[slot]
        n_reg = min(plen // self.pt,
                    (eng.max_seq - eng.T) // self.pt)
        for i in range(n_reg):
            self._register(int(self.tables[slot, i]), chain[i])
        tok0 = int(jnp.argmax(logits[0, -1, :]))
        self.lengths[slot] = plen
        self.ready[slot] = True
        self._nxt = self._nxt.at[slot, 0, 0].set(tok0)
        del self._pending[slot]
        return tok0

    # -- decode ------------------------------------------------------------
    def reserve_decode(self, slots: List[int]) -> List[int]:
        """Grow block tables to cover the next T decode writes.

        Returns slots the pool cannot serve (after reclaiming every cached
        page) — the engine evicts those with ``reason="kv_pages"`` and
        calls again, so freed pages immediately serve the survivors.  At
        most ONE victim is returned per call: several slots crossing a
        page boundary in the same iteration must not all be evicted when
        freeing a single one would let the rest grow.
        """
        victims: List[int] = []
        for slot in slots:
            ln = int(self.lengths[slot])
            last = min(ln + self.engine.T, self.engine.max_seq) - 1
            need = last // self.pt + 1 - int(self.n_rows[slot])
            if need <= 0:
                continue
            got = self._take_pages(need)
            if got is None:
                victims.append(slot)
                return victims
            r0 = int(self.n_rows[slot])
            self.tables[slot, r0:r0 + need] = got
            self.n_rows[slot] = r0 + need
            for pg in got:
                self._ref[pg] += 1
            self.engine.session.emit(
                "progress", "kv.alloc", uid=self._uid(slot), slot=slot,
                pages=need, payload_bytes=need * self.page_bytes)
        return victims

    def _uid(self, slot: int) -> int:
        tix = self.engine._slot_tix[slot]
        return tix.uid if tix is not None else -1

    def decode(self) -> np.ndarray:
        # still-prefilling slots decode as empty scratch slots: their block
        # tables and lengths are masked so the launch never touches their
        # half-written pages
        tables = np.where(self.ready[:, None], self.tables, 0)
        lengths = np.where(self.ready, self.lengths, 0).astype(np.int32)
        self.k_pool, self.v_pool, toks, self._nxt = self._decode_slots(
            self.engine.params, self.k_pool, self.v_pool,
            jnp.asarray(tables), jnp.asarray(lengths), self._nxt)
        blocks = np.asarray(toks)                    # [B, T] host sync
        self.lengths[self.ready] += self.engine.T
        return blocks

    def release(self, slot: int) -> None:
        n = int(self.n_rows[slot])
        freed = 0
        for i in range(n):
            self._drop_ref(int(self.tables[slot, i]))
            freed += 1
        if freed:
            self.engine.session.emit(
                "progress", "kv.free", uid=self._uid(slot), slot=slot,
                pages=freed, payload_bytes=freed * self.page_bytes)
        self.tables[slot, :] = 0
        self.n_rows[slot] = 0
        self.lengths[slot] = 0
        self.ready[slot] = False
        self._chain.pop(slot, None)
        self._pending.pop(slot, None)

    def stats(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "backend": self.name,
            "prefill_chunk": self.chunk,
            "page_tokens": self.pt,
            "pages_total": self.pages,
            "pages_in_use": self.pages_in_use,
            "pages_peak": self.pages_peak,
            "pages_allocated": self.pages_allocated,
            "pages_reused": self.pages_reused,
            "pages_cached": len(self._cached),
            "prefix_hits": self.prefix_hits,
            "prefix_hit_tokens": self.prefix_hit_tokens,
        }
        out.update(self.counters.to_dict())
        return out


def make_kv(engine: "Server", kind: str = "dense",
            page_tokens: int = 16, pages: Optional[int] = None,
            prefill_chunk: int = 0) -> KVCacheManager:
    if kind == "dense":
        return DenseKV(engine, prefill_chunk=prefill_chunk)
    if kind == "paged":
        return PagedKV(engine, page_tokens=page_tokens, pages=pages,
                       prefill_chunk=prefill_chunk)
    raise ValueError(f"unknown kv backend {kind!r}; "
                     f"expected one of {KV_BACKENDS}")
