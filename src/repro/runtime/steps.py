"""Family-agnostic train / prefill / decode step builders.

Each builder returns a pure function suitable for ``jax.jit`` with explicit
in/out shardings; activation sharding constraints (sequence parallelism on
the residual stream) are applied inside the model via the ``constraint``
hook so XLA's SPMD partitioner sees a fully-annotated program.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..configs.shapes import ShapeConfig
from ..core.session import ambient_span
from ..optim.adamw import AdamWState, adamw_init, adamw_update
from ..optim.compression import ef_compress_update
from ..optim.schedule import cosine_schedule

__all__ = ["make_train_step", "make_prefill_step", "make_decode_step",
           "make_input_specs", "init_all"]


def make_train_step(model, cfg: ModelConfig, peak_lr: float = 3e-4,
                    warmup: int = 100, total_steps: int = 10000,
                    grad_compression: Optional[str] = None
                    ) -> Callable:
    def grads_of(params, batch):
        def loss_fn(p):
            loss, metrics = model.loss(p, batch)
            return loss, metrics
        return jax.value_and_grad(loss_fn, has_aux=True)(params)

    def train_step(params, opt_state: AdamWState, batch, ef_state=None):
        M = max(1, cfg.microbatch)
        if M > 1:
            # gradient accumulation: M sequential microbatches per step —
            # activation live-set shrinks M×, grads accumulate in fp32
            mb = jax.tree_util.tree_map(
                lambda x: x.reshape((M, x.shape[0] // M) + x.shape[1:]),
                batch)
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(acc, one):
                (l, m), g = grads_of(params, one)
                acc = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), acc, g)
                return acc, (l, m)

            gsum, (losses, metricses) = jax.lax.scan(body, zeros, mb)
            grads = jax.tree_util.tree_map(lambda g: g / M, gsum)
            loss = jnp.mean(losses)
            metrics = jax.tree_util.tree_map(jnp.mean, metricses)
        else:
            (loss, metrics), grads = grads_of(params, batch)
        if grad_compression == "int8" and ef_state is not None:
            grads, ef_state = ef_compress_update(grads, ef_state)
        lr = cosine_schedule(opt_state.step, warmup, total_steps, peak_lr)
        new_params, new_opt, om = adamw_update(grads, opt_state, params, lr)
        out_metrics = {"loss": loss, **metrics, **om}
        if ef_state is not None:
            return new_params, new_opt, out_metrics, ef_state
        return new_params, new_opt, out_metrics

    return train_step


def make_prefill_step(model, cfg: ModelConfig, max_seq: int) -> Callable:
    if cfg.family == "audio":
        def prefill_step(params, batch):
            return model.prefill(params, batch["frames"], batch["tokens"],
                                 max_seq)
    elif cfg.family == "vlm":
        def prefill_step(params, batch):
            return model.prefill(params, batch["tokens"], max_seq,
                                 patch_embeds=batch["patch_embeds"])
    else:
        def prefill_step(params, batch):
            return model.prefill(params, batch["tokens"], max_seq)
    return prefill_step


def make_decode_step(model, cfg: ModelConfig) -> Callable:
    def decode_step(params, state, tokens):
        return model.decode_step(params, state, tokens)
    return decode_step


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — no allocation, dry-run pattern)
# ---------------------------------------------------------------------------
def make_input_specs(cfg: ModelConfig, shape: ShapeConfig
                     ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Batch stand-ins for one (arch × shape) cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.dtype(cfg.param_dtype)
    tok = lambda b, s: jax.ShapeDtypeStruct((b, s), i32)

    if shape.kind == "decode":
        return {"tokens": tok(B, 1)}

    if cfg.family == "audio":
        S_dec = max(S // cfg.enc_seq_ratio, 1)
        return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), bf16),
                "tokens": tok(B, S_dec), "labels": tok(B, S_dec)}
    if cfg.family == "vlm":
        S_text = S - cfg.n_patches
        out = {"tokens": tok(B, S_text),
               "patch_embeds": jax.ShapeDtypeStruct(
                   (B, cfg.n_patches, cfg.d_model), bf16)}
        if shape.kind == "train":
            out["labels"] = tok(B, S_text)
        return out
    out = {"tokens": tok(B, S)}
    if shape.kind == "train":
        out["labels"] = tok(B, S)
    return out


def init_all(model, cfg: ModelConfig, key: Optional[jax.Array] = None
             ) -> Tuple[Any, AdamWState]:
    """(params, opt_state) — run under ``jax.eval_shape`` for the dry-run."""
    key = jax.random.PRNGKey(0) if key is None else key
    # span only materialises when a TraceSession is ambient (the trainer
    # activates its own); the dry-run path stays session-free
    with ambient_span("steps.init_all"):
        params = model.init_params(key)
        return params, adamw_init(params)
