"""Sharded checkpointing: per-leaf .npy shards + JSON manifest.

* atomic: written to ``<dir>/tmp.<step>`` and renamed on completion, so a
  crash mid-save never corrupts the latest checkpoint;
* async: ``save_async`` snapshots to host memory synchronously (cheap) and
  writes to disk on a background thread, overlapping the next train steps;
* restart-exact: the manifest stores the step and data-pipeline cursor, so
  restore() resumes bit-exact with the deterministic pipeline.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

__all__ = ["save", "save_async", "restore", "latest_step", "CheckpointManager"]

_MANIFEST = "manifest.json"


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, name))


def _flatten(tree: Any) -> List[Tuple[str, Any]]:
    leaves = jax.tree_util.tree_leaves_with_path(tree)
    out = []
    for path, leaf in leaves:
        key = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        out.append((key, leaf))
    return out


def save(ckpt_dir: str, step: int, tree: Any,
         extra: Optional[Dict[str, Any]] = None) -> str:
    """Synchronous atomic checkpoint write."""
    tmp = os.path.join(ckpt_dir, f"tmp.{step}")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest: Dict[str, Any] = {"step": step, "leaves": [],
                                "extra": extra or {}}
    for i, (key, leaf) in enumerate(_flatten(tree)):
        arr = np.ascontiguousarray(np.asarray(leaf))
        fname = f"leaf_{i:05d}.npy"
        # ml_dtypes (bf16/f8) don't round-trip np.save — store raw bytes
        np.save(os.path.join(tmp, fname), arr.view(np.uint8).reshape(-1))
        manifest["leaves"].append({"key": key, "file": fname,
                                   "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def save_async(ckpt_dir: str, step: int, tree: Any,
               extra: Optional[Dict[str, Any]] = None) -> threading.Thread:
    """Snapshot device buffers to host now; write to disk in the background."""
    host_tree = jax.tree_util.tree_map(lambda x: np.asarray(x), tree)
    t = threading.Thread(target=save, args=(ckpt_dir, step, host_tree, extra),
                         daemon=True)
    t.start()
    return t


def latest_step(ckpt_dir: str) -> Optional[int]:
    try:
        steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                 if d.startswith("step_")]
    except FileNotFoundError:
        return None
    return max(steps) if steps else None


def restore(ckpt_dir: str, tree_like: Any, step: Optional[int] = None
            ) -> Tuple[Any, int, Dict[str, Any]]:
    """Restore into the structure (and shardings) of ``tree_like``."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, _MANIFEST)) as f:
        manifest = json.load(f)
    by_key = {l["key"]: l for l in manifest["leaves"]}
    flat = _flatten(tree_like)
    new_leaves = []
    for key, leaf in flat:
        meta = by_key.get(key)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        raw = np.load(os.path.join(d, meta["file"]))
        dt = _np_dtype(meta["dtype"])
        arr = raw.view(dt).reshape(meta["shape"])
        sharding = getattr(leaf, "sharding", None)
        if sharding is not None and hasattr(leaf, "dtype"):
            new_leaves.append(jax.device_put(arr, sharding))
        else:
            new_leaves.append(arr)
    treedef = jax.tree_util.tree_structure(tree_like)
    return (jax.tree_util.tree_unflatten(treedef, new_leaves), step,
            manifest.get("extra", {}))


class CheckpointManager:
    """keep_last_n retention + async save handles."""

    def __init__(self, ckpt_dir: str, keep_last_n: int = 3,
                 every_steps: int = 100) -> None:
        self.dir = ckpt_dir
        self.keep = keep_last_n
        self.every = every_steps
        self._pending: List[threading.Thread] = []
        os.makedirs(ckpt_dir, exist_ok=True)

    def maybe_save(self, step: int, tree: Any,
                   extra: Optional[Dict[str, Any]] = None) -> bool:
        if step % self.every:
            return False
        self._pending.append(save_async(self.dir, step, tree, extra))
        self._gc()
        return True

    def wait(self) -> None:
        for t in self._pending:
            t.join(timeout=60)
        self._pending.clear()

    def _gc(self) -> None:
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)
