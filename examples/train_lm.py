"""End-to-end training driver: ~100M-param dense LM for a few hundred steps.

Exercises the full production path: deterministic pipeline -> multi-step
graph launch -> AdamW (fp32 master) -> async checkpoints -> restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--arch qwen3-8b]
"""
import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import ARCHS
from repro.configs.shapes import ShapeConfig
from repro.runtime.trainer import Trainer


def hundred_m_variant(name: str):
    """Scale an assigned arch down to ~100M params (same family/shape laws)."""
    cfg = ARCHS[name]
    return dataclasses.replace(
        cfg, n_layers=max(2, min(cfg.n_layers, 10)),
        d_model=640, n_heads=10, n_kv_heads=5 if cfg.n_kv_heads else 0,
        head_dim=64, d_ff=2560,
        vocab_size=32000, pad_vocab_to=0, pad_heads_to=0,
        n_experts=min(cfg.n_experts, 8),
        remat=False, attn_chunk=0, fsdp=False, seq_shard=False)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b", choices=list(ARCHS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--steps-per-launch", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = hundred_m_variant(args.arch)
    total, active = cfg.param_counts()
    print(f"training {cfg.name}-100m variant: {total/1e6:.0f}M params "
          f"({active/1e6:.0f}M active)")
    shape = ShapeConfig("train_lm", args.seq, args.batch, "train")
    tr = Trainer(cfg, shape, steps_per_launch=args.steps_per_launch,
                 ckpt_dir=args.ckpt_dir, ckpt_every=50, peak_lr=6e-4)
    if tr.maybe_restore():
        print(f"restored from checkpoint at step {tr.step}")
    out = tr.train(args.steps)
    first = tr.metrics_log[0]["loss"] if tr.metrics_log else float("nan")
    print(f"steps={out['steps']} wall={out['wall_s']:.1f}s "
          f"doorbells={out['doorbells']} "
          f"loss {first:.3f} -> {out['final_loss']:.3f}")
    print("submission report:", tr.submission_report())


if __name__ == "__main__":
    main()
