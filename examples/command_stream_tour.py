"""Tour of the command-stream methodology (the paper, end to end).

One :class:`repro.core.TraceSession` spans the whole tour — the watchpoint
analogue: every submission passes through it exactly once, whichever
subsystem made it.

1. Listing-1 analogue: decode the submission of a serve step (``compile``).
2. §6.2 analogue: inline vs direct data movement (``transfer``).
3. §6.3 analogue: the command-footprint law (``graph_launch``/``dispatch``).
4. The merged timeline: all of the above interleaved in submission order.
5. Fleet-wide capture: two *separate processes*, each with its own tagged
   session and its own monotonic clock, merged by ``repro.obs.aggregate``
   into one cross-process submission-ordered timeline (barrier-aligned).
6. Causal attribution: spans stamp every command with the request / decode
   iteration that caused it; ``SpanProfile`` rolls doorbells, payload and
   wall time up per span path with streaming percentile histograms, the
   timeline exports to Perfetto, and the scored numbers persist in the
   metrics store.

    PYTHONPATH=src python examples/command_stream_tour.py
"""
import os
import socket
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import SMOKE_ARCHS
from repro.core import ExecGraph, TraceSession, render_submission
from repro.models import get_model


def tour_1_listing(sess: TraceSession) -> None:
    print("=" * 72)
    print("1. Command-stream reconstruction (Listing 1 analogue)")
    print("=" * 72)
    cfg = SMOKE_ARCHS["mamba2-780m"]
    model = get_model(cfg)
    import jax
    params = model.init_params(jax.random.PRNGKey(0))
    state = model.init_decode_state(2, 32)
    tok = np.zeros((2, 1), np.int32)
    cs = sess.capture.lower_and_compile("serve_step", model.decode_step,
                                        args=(params, state, tok))
    print(render_submission(cs, max_entries=18))


def tour_2_dma(sess: TraceSession) -> None:
    print("\n" + "=" * 72)
    print("2. Data-movement protocols (inline vs direct, §6.2)")
    print("=" * 72)
    sess.mover.threshold = 24 * 1024            # the paper's switch point
    for nbytes in (64, 4096, 16 * 1024, 64 * 1024, 1 << 20):
        x = np.random.default_rng(0).integers(
            0, 255, size=nbytes).astype(np.uint8)
        _, rec = sess.mover.put(x)
        print(f"  {nbytes:>9d} B -> {rec.mode:7s} "
              f"complete={rec.complete_s*1e6:8.1f} us "
              f"bw={rec.bandwidth_gib_s:8.3f} GiB/s")
    print("  protocol counts:", sess.mover.stats(),
          "(threshold is a knob — CUDA's is opaque)")


def tour_3_graphs(sess: TraceSession) -> None:
    print("\n" + "=" * 72)
    print("3. Launch modes & the command-footprint law (§6.3)")
    print("=" * 72)
    for K in (10, 100):
        for mode in ("per_op", "graphed", "multistep"):
            g = ExecGraph(chain_len=K, width=1024)
            g.launch(mode, session=sess)         # warm
            _, st = g.launch(mode, session=sess)
            print(f"  K={K:4d} {mode:10s} doorbells={st.doorbells:4d} "
                  f"footprint={st.command_bytes:8d}B "
                  f"launch={st.launch_s*1e6:8.1f}us")
    print("  -> footprint and doorbells, not node count, set launch cost")


def tour_4_timeline(sess: TraceSession) -> None:
    print("\n" + "=" * 72)
    print("4. The unified timeline (one watchpoint saw all of the above)")
    print("=" * 72)
    print(sess.report(max_events=24))


def _fleet_worker(start_barrier, outdir: str, pid: int) -> None:
    """One simulated fleet process: tagged session, own clock, own shard."""
    from repro.core import TraceSession

    time.sleep(0.03 * pid)                 # deliberately skew session t0
    path = os.path.join(outdir, f"trace.p{pid}.jsonl")
    with TraceSession(f"fleet_proc{pid}", jsonl_path=path,
                      tags={"host": socket.gethostname(),
                            "process": pid}) as sess:
        start_barrier.wait()               # the shared real-world moment
        sess.barrier("fleet.sync")         # -> obs.barrier alignment event
        for step in range(3):
            sess.emit("dispatch", f"decode_step{step}",
                      dur_s=1e-4 * (pid + 1), payload_bytes=512)
            time.sleep(0.01)
        sess.emit("transfer", "kv_pull", dur_s=2e-4,
                  payload_bytes=1 << 16, mode="direct")


def tour_5_fleet() -> None:
    print("\n" + "=" * 72)
    print("5. Fleet-wide aggregation (two processes, one merged timeline)")
    print("=" * 72)
    import multiprocessing as mp

    from repro.obs import aggregate

    ctx = mp.get_context("spawn")
    outdir = tempfile.mkdtemp(prefix="fleet_tour_")
    start = ctx.Barrier(2)
    procs = [ctx.Process(target=_fleet_worker, args=(start, outdir, pid))
             for pid in (0, 1)]
    for p in procs:
        p.start()
    for p in procs:
        p.join()
    merged = aggregate(sorted(
        os.path.join(outdir, f) for f in os.listdir(outdir)))
    print(merged.report(max_events=16))
    for shard, al in merged.summary()["alignment"].items():
        print(f"  shard {shard}: offset={al['offset_s']*1e3:+.3f} ms "
              f"via {al['mode']}")
    print("  -> per-process clocks re-based onto one submission order")


def tour_6_attribution() -> None:
    print("\n" + "=" * 72)
    print("6. Causal attribution (spans -> percentiles -> Perfetto -> store)")
    print("=" * 72)
    from repro.obs import SpanProfile, to_chrome_trace
    from repro.obs.store import MetricsStore

    prof = SpanProfile(name="tour")
    outdir = tempfile.mkdtemp(prefix="attr_tour_")
    trace_path = os.path.join(outdir, "trace.jsonl")
    with TraceSession("attribution", jsonl_path=trace_path,
                      sinks=[prof]) as sess:
        for uid in range(4):
            # scoped spans nest via contextvar; every emit inside is
            # stamped with the full ancestor chain and rolls up to it
            with sess.span("request", uid=uid):
                with sess.span("prefill"):
                    sess.emit("dispatch", "prefill_launch",
                              dur_s=2e-4, payload_bytes=4096)
                for it in range(3):
                    with sess.span("decode_iter", it=it):
                        sess.emit("graph_launch", "decode_graph",
                                  dur_s=1e-4 * (1 + uid),
                                  doorbells=1, command_bytes=4610)
        # manual handle: overlapping background work, *declared* costs
        h = sess.start_span("kv_migration")
        h.end(doorbells=2, payload=1 << 16)
    print(prof.report())
    req = prof.path("request")
    print(f"  request: doorbells/span p50={req['doorbells_per_span']['p50']:.1f}"
          f" wall p99={req['wall_s']['p99']*1e3:.2f} ms")

    trace = to_chrome_trace(sess.timeline(), trace_name="tour")
    n_slices = sum(1 for t in trace["traceEvents"]
                   if t.get("cat") == "span" and t["ph"] in ("X", "b"))
    print(f"  Perfetto export: {len(trace['traceEvents'])} trace events, "
          f"{n_slices} span slices (load at ui.perfetto.dev)")

    store = MetricsStore(root=os.path.join(outdir, "metrics"))
    rec = store.append("tour", prof.store_metrics())
    print(f"  stored {len(rec.metrics)} metrics as run {rec.run_id} "
          f"@ {rec.git_sha}")
    print("  -> every doorbell now has a *cause*, not just a timestamp")


if __name__ == "__main__":
    with TraceSession("command_stream_tour") as sess:
        tour_1_listing(sess)
        tour_2_dma(sess)
        tour_3_graphs(sess)
    tour_4_timeline(sess)
    tour_5_fleet()
    tour_6_attribution()
