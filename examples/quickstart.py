"""Quickstart: one TraceSession from capture to report.

Runs a reduced deepseek-7b config for a few steps with ALL instrumentation
flowing through a single :class:`repro.core.TraceSession` — compile events
from the capture boundary, dispatch events from the doorbell-wrapped train
step, and progress fences — then prints the Listing-1-style decoded
submission report plus the unified, submission-ordered event timeline.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import SMOKE_ARCHS
from repro.configs.shapes import ShapeConfig
from repro.core import TraceSession, analyze, render_submission
from repro.models import get_model
from repro.runtime.steps import init_all, make_train_step
from repro.runtime.trainer import Trainer


def main() -> None:
    cfg = SMOKE_ARCHS["deepseek-7b"]
    shape = ShapeConfig("quickstart", seq_len=64, global_batch=4, kind="train")

    with TraceSession("quickstart") as sess:
        # --- 1. capture the command stream at the submission boundary ----
        model = get_model(cfg)
        params, opt = init_all(model, cfg)
        from repro.data.pipeline import SyntheticTokens
        batch = SyntheticTokens(cfg, shape).batch_at(0)
        cs = sess.capture.lower_and_compile(
            "train_step", make_train_step(model, cfg),
            args=(params, opt, batch))
        print(render_submission(cs, max_entries=25))

        # --- 2. three-term roofline from the captured stream --------------
        rep = analyze(cs, chips=1, model_flops_total=6 * 115008 * 4 * 64)
        print(f"\nroofline: compute={rep.compute_s*1e6:.1f}us "
              f"memory={rep.memory_s*1e6:.1f}us "
              f"collective={rep.collective_s*1e6:.1f}us "
              f"-> {rep.bottleneck}-bound")

        # --- 3. train a few steps on the SAME session ----------------------
        tr = Trainer(cfg, shape, steps_per_launch=2, session=sess)
        out = tr.train(4)
        print(f"\ntrained {out['steps']} steps in {out['wall_s']:.1f}s, "
              f"{out['doorbells']} doorbells "
              f"({out['steps_per_doorbell']:.0f} steps/doorbell), "
              f"final loss {out['final_loss']:.3f}")

    # --- 4. the unified timeline: compile, dispatch, progress interleaved --
    print()
    print(sess.report(max_events=20))


if __name__ == "__main__":
    main()
