"""Serving example: batched requests, prefill + multi-token decode launches.

Shows the doorbell economy of multi-token graph launch (the paper's §6.3
lesson applied to decoding).

    PYTHONPATH=src python examples/serve_lm.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.configs import SMOKE_ARCHS
from repro.runtime.server import Request, Server


def main() -> None:
    cfg = SMOKE_ARCHS["qwen3-8b"]

    def mk():
        rng = np.random.default_rng(0)   # fresh rng: identical prompts per T
        return [Request(i, rng.integers(0, cfg.vocab_size, size=6)
                        .astype(np.int32), max_new_tokens=12)
                for i in range(4)]

    for T in (1, 4):
        srv = Server(cfg, batch_size=4, max_seq=64, tokens_per_launch=T,
                     seed=0)
        reqs = mk()
        out = srv.serve(reqs)
        print(f"tokens_per_launch={T}: {out['new_tokens']} tokens, "
              f"{out['doorbells']} doorbells "
              f"({out['tokens_per_doorbell']:.1f} tok/doorbell), "
              f"wall {out['wall_s']:.2f}s")
        print("  first request tokens:", reqs[0].tokens)


if __name__ == "__main__":
    main()
