"""Graph launch modes: doorbell counts + command-footprint law (§6.3)."""
import numpy as np
import pytest

from repro.core import ExecGraph, MultiStepLauncher

import jax
import jax.numpy as jnp


@pytest.mark.parametrize("mode", ["per_op", "graphed", "multistep"])
def test_launch_modes_correct(mode):
    g = ExecGraph(chain_len=12, width=64)
    y, st = g.launch(mode)
    np.testing.assert_allclose(np.asarray(y), np.asarray(g.reference()),
                               rtol=1e-5)
    assert st.doorbells == (12 if mode == "per_op" else 1)


def test_footprint_scaling_law():
    """per_op: bytes ∝ K; graphed: grows with K; multistep: O(1)."""
    sizes = {}
    for K in (8, 32):
        for mode in ("per_op", "graphed", "multistep"):
            g = ExecGraph(chain_len=K, width=64)
            g.upload(mode)
            sizes[(mode, K)] = g.command_footprint(mode)[0]
    assert sizes[("per_op", 32)] == 4 * sizes[("per_op", 8)]
    assert sizes[("graphed", 32)] > sizes[("graphed", 8)]
    ratio = sizes[("multistep", 32)] / sizes[("multistep", 8)]
    assert ratio < 1.1  # O(1) footprint


def test_multistep_launcher_matches_sequential():
    def step(carry, b):
        return carry + b, carry.sum()

    launcher = MultiStepLauncher(step, k=5)
    carry = jnp.zeros((4,))
    batches = jnp.ones((5, 4))
    (final, auxs) = launcher(carry, batches)
    np.testing.assert_allclose(np.asarray(final), 5 * np.ones(4), rtol=1e-6)
    assert launcher.tracker.count == 1  # ONE doorbell for 5 steps
