"""Model-layer unit tests: attention paths, MoE dispatch, SSD, decode==prefill."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKE_ARCHS
from repro.models import get_model
from repro.models.attention import (chunked_causal_attention,
                                    dense_causal_attention)
from repro.models.mamba import ssd_chunked
from repro.models.moe import moe_dense, moe_sorted, init_moe

rng = np.random.default_rng(7)
KEY = jax.random.PRNGKey(0)


def test_chunked_attention_matches_dense():
    B, S, H, hd = 2, 256, 4, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)).astype(np.float32))
    ref = dense_causal_attention(q, k, v, causal=True)
    for chunk in (32, 64, 128):
        out = chunked_causal_attention(q, k, v, chunk=chunk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)


def test_moe_sorted_matches_dense_with_full_capacity():
    cfg = dataclasses.replace(
        SMOKE_ARCHS["qwen2-moe-a2.7b"], n_shared_experts=0,
        capacity_factor=float(8) / 4)  # C = S -> no drops possible
    p = init_moe(KEY, cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 32, cfg.d_model)).astype(np.float32))
    y_d, _ = moe_dense(p, cfg, x)
    y_s, _ = moe_sorted(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_d),
                               rtol=2e-4, atol=2e-4)


def test_moe_capacity_drops_are_bounded():
    cfg = dataclasses.replace(SMOKE_ARCHS["qwen2-moe-a2.7b"],
                              n_shared_experts=0, capacity_factor=1.0)
    p = init_moe(KEY, cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, 64, cfg.d_model)).astype(np.float32))
    y, _ = moe_sorted(p, cfg, x)
    assert np.all(np.isfinite(np.asarray(y)))


def test_decode_matches_prefill_logits():
    """Greedy decode after prefill == teacher-forced forward (dense arch)."""
    cfg = SMOKE_ARCHS["deepseek-7b"]
    model = get_model(cfg)
    params = model.init_params(KEY)
    toks = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
    # full forward logits at each position
    x, _ = model.hidden_states(params, toks, mode="eval")
    from repro.models.layers import unembed
    full_logits = unembed(params["emb"], x)
    # prefill on first 4, then decode tokens 4..7 one by one
    state, logits = jax.jit(lambda p, t: model.prefill(p, t, 16))(
        params, toks[:, :4])
    np.testing.assert_allclose(np.asarray(logits[0, -1], np.float32),
                               np.asarray(full_logits[0, 3], np.float32),
                               rtol=2e-2, atol=2e-2)
    dec = jax.jit(model.decode_step)
    for t in range(4, 8):
        state, logits = dec(params, state, toks[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(logits[0, 0], np.float32),
                                   np.asarray(full_logits[0, t], np.float32),
                                   rtol=2e-2, atol=2e-2)


def test_ssd_matches_naive_recurrence():
    B, S, H, P, N = 1, 48, 2, 8, 4
    xh = rng.normal(size=(B, S, H, P)).astype(np.float32)
    dt = np.abs(rng.normal(size=(B, S, H))).astype(np.float32) * 0.5
    A = -np.abs(rng.normal(size=(H,))).astype(np.float32)
    Bc = rng.normal(size=(B, S, N)).astype(np.float32)
    Cc = rng.normal(size=(B, S, N)).astype(np.float32)
    h = np.zeros((B, H, P, N), np.float32)
    ys = []
    for t in range(S):
        dA = np.exp(dt[:, t] * A[None])
        h = h * dA[..., None, None] + np.einsum(
            "bh,bn,bhp->bhpn", dt[:, t], Bc[:, t], xh[:, t])
        ys.append(np.einsum("bn,bhpn->bhp", Cc[:, t], h))
    y_ref = np.stack(ys, 1)
    y, hf = ssd_chunked(jnp.asarray(xh), jnp.asarray(dt), jnp.asarray(A),
                        jnp.asarray(Bc), jnp.asarray(Cc), chunk=16)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(hf), h, rtol=3e-4, atol=3e-4)


def test_mamba_decode_matches_block():
    """Sequential decode steps == full-sequence mamba block."""
    cfg = SMOKE_ARCHS["mamba2-780m"]
    model = get_model(cfg)
    params = model.init_params(KEY)
    toks = jax.random.randint(KEY, (1, 12), 0, cfg.vocab_size)
    x_full, _ = model.hidden_states(params, toks, mode="eval")
    from repro.models.layers import unembed
    full_logits = unembed(params["emb"], x_full)
    state = model.init_decode_state(1, 16)
    dec = jax.jit(model.decode_step)
    for t in range(12):
        state, logits = dec(params, state, toks[:, t:t + 1])
        np.testing.assert_allclose(
            np.asarray(logits[0, 0], np.float32),
            np.asarray(full_logits[0, t], np.float32), rtol=4e-2, atol=4e-2)


def test_vlm_prefill_decode_continuity():
    """VLM prefill (patches + text) fills the KV cache correctly."""
    cfg = SMOKE_ARCHS["llava-next-34b"]
    model = get_model(cfg)
    params = model.init_params(KEY)
    B, S_text = 1, 10
    toks = jax.random.randint(KEY, (B, S_text), 0, cfg.vocab_size)
    patches = jax.random.normal(KEY, (B, cfg.n_patches, cfg.d_model),
                                jnp.bfloat16)
    x = model._inject(params, toks, patches)
    xf = model._forward_embeds(params, x, mode="eval")
    from repro.models.layers import unembed
    full_logits = unembed(params["emb"], xf)
    state, lg = jax.jit(
        lambda p, t, pe: model.prefill(p, t, 64, patch_embeds=pe))(
        params, toks[:, :6], patches)
    np.testing.assert_allclose(
        np.asarray(lg[0, -1], np.float32),
        np.asarray(full_logits[0, cfg.n_patches + 5], np.float32),
        rtol=4e-2, atol=4e-2)
    dec = jax.jit(model.decode_step)
    for t in range(6, S_text):
        state, lg = dec(params, state, toks[:, t:t + 1])
        np.testing.assert_allclose(
            np.asarray(lg[0, 0], np.float32),
            np.asarray(full_logits[0, cfg.n_patches + t], np.float32),
            rtol=4e-2, atol=4e-2)


def test_whisper_decode_matches_teacher_forced():
    cfg = SMOKE_ARCHS["whisper-medium"]
    model = get_model(cfg)
    params = model.init_params(KEY)
    B, S_enc, S_dec = 1, 32, 8
    frames = jax.random.normal(KEY, (B, S_enc, cfg.d_model), jnp.bfloat16)
    toks = jax.random.randint(KEY, (B, S_dec), 0, cfg.vocab_size)
    enc_out = model.encode(params, frames, mode="eval")
    x = model.decode_train(params, toks, enc_out, mode="eval")
    from repro.models.layers import unembed
    full_logits = unembed(params["emb"], x)
    assert np.all(np.isfinite(np.asarray(full_logits, np.float32)))
