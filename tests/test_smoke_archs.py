"""Per-arch smoke tests: REDUCED config of the same family, one train step
and one decode step on CPU, asserting shapes + finiteness (assignment (f))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SMOKE_ARCHS
from repro.models import get_model

B, S = 2, 64
KEY = jax.random.PRNGKey(0)


def _batch(cfg):
    tok = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    if cfg.family == "audio":
        return {"frames": jax.random.normal(KEY, (B, S, cfg.d_model),
                                            jnp.bfloat16),
                "tokens": tok[:, :S // 4], "labels": tok[:, :S // 4]}
    if cfg.family == "vlm":
        return {"tokens": tok, "labels": tok,
                "patch_embeds": jax.random.normal(
                    KEY, (B, cfg.n_patches, cfg.d_model), jnp.bfloat16)}
    return {"tokens": tok, "labels": tok}


@pytest.mark.parametrize("name", list(SMOKE_ARCHS))
def test_smoke_train_step(name):
    cfg = SMOKE_ARCHS[name]
    model = get_model(cfg)
    params = model.init_params(KEY)
    batch = _batch(cfg)

    def loss_fn(p):
        l, m = model.loss(p, batch)
        return l

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("name", list(SMOKE_ARCHS))
def test_smoke_decode_step(name):
    cfg = SMOKE_ARCHS[name]
    model = get_model(cfg)
    params = model.init_params(KEY)
    state = model.init_decode_state(B, 128)
    tok = jax.random.randint(KEY, (B, 1), 0, cfg.vocab_size)
    new_state, logits = jax.jit(model.decode_step)(params, state, tok)
    assert logits.shape[0] == B
    assert logits.shape[-1] == cfg.vocab_padded
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    # state structurally unchanged
    assert (jax.tree_util.tree_structure(new_state)
            == jax.tree_util.tree_structure(state))


@pytest.mark.parametrize("name", list(ARCHS))
def test_full_configs_match_published(name):
    """Exact full configs instantiate (shapes only) with sane param counts."""
    cfg = ARCHS[name]
    total, active = cfg.param_counts()
    assert 0 < active <= total
    expected = {"jamba-v0.1-52b": 52e9, "grok-1-314b": 314e9,
                "qwen2-moe-a2.7b": 14.3e9, "gemma-2b": 2.5e9,
                "deepseek-7b": 6.9e9, "llama3-405b": 405e9,
                "qwen3-8b": 8.2e9, "whisper-medium": 1.0e9,
                "mamba2-780m": 0.78e9, "llava-next-34b": 34e9}[name]
    assert total == pytest.approx(expected, rel=0.35)


def test_qwen2_moe_active_params_match_name():
    total, active = ARCHS["qwen2-moe-a2.7b"].param_counts()
    assert active == pytest.approx(2.7e9, rel=0.05)  # the "A2.7B" in the name
