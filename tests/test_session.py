"""Unified TraceSession: ordering, sinks, ambient activation, legacy parity."""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CommandStreamCapture, DoorbellTracker, ExecGraph,
                        HybridMover, JsonlSink, ProgressTracker, RingBufferSink,
                        TraceEvent, TraceSession, current_session)


# -- event ordering across mixed kinds -------------------------------------

def test_mixed_kinds_share_one_monotonic_sequence():
    with TraceSession("mix") as sess:
        cs = sess.capture.lower_and_compile("f", lambda x: x * 2,
                                            args=(jnp.ones(4),))
        f = sess.wrap(cs.compiled, "f_dispatch")
        f(jnp.ones(4))
        sess.mover.put(np.zeros(8, np.float32))
        f(jnp.ones(4))
        tok = sess.progress.release(jnp.ones(2))
        sess.progress.wait(tok)
    evs = sess.timeline()
    assert [e.seq for e in evs] == list(range(len(evs)))
    assert [e.kind for e in evs] == ["compile", "dispatch", "transfer",
                                    "dispatch", "progress", "progress"]
    # one shared timestamp base: t is non-negative and bounded by the wall
    assert all(e.t >= 0 for e in evs)


def test_timeline_filters_and_graph_launch_interleaving():
    with TraceSession("graphs") as sess:
        g = ExecGraph(chain_len=3, width=16)
        g.launch("per_op", session=sess)
        g.launch("multistep", session=sess)
    launches = sess.timeline(kinds="graph_launch")
    assert [e.meta["mode"] for e in launches] == ["per_op", "multistep"]
    assert launches[0].meta["doorbells"] == 3
    assert launches[1].meta["doorbells"] == 1
    # the per-op doorbell rings appear on the same timeline, before the
    # multistep launch event
    dispatches = sess.timeline(kinds="dispatch", name="per_op_dispatch")
    assert len(dispatches) == 3
    assert all(d.seq < launches[1].seq for d in dispatches)


# -- ring buffer bounding ---------------------------------------------------

def test_ring_buffer_bounded_keeps_latest():
    sess = TraceSession("ring", ring_size=10)
    for i in range(25):
        sess.emit("dispatch", f"d{i}")
    assert sess.n_events == 25
    evs = sess.timeline()
    assert len(evs) == 10
    assert [e.name for e in evs] == [f"d{i}" for i in range(15, 25)]
    assert sess.ring.dropped == 15
    assert sess.summary()["dropped"] == 15


def test_emit_rejects_unknown_kind():
    sess = TraceSession("bad")
    with pytest.raises(ValueError):
        sess.emit("doorbell", "nope")


# -- JSONL sink round-trip --------------------------------------------------

def test_jsonl_sink_round_trip(tmp_path):
    path = os.path.join(tmp_path, "trace.jsonl")
    with TraceSession("jsonl", jsonl_path=path) as sess:
        sess.emit("dispatch", "a", dur_s=1e-3, payload_bytes=64, mode="x")
        sess.emit("transfer", "b", complete_s=2e-3)
    loaded = JsonlSink.load(path)
    assert [e.to_dict() for e in loaded] == \
        [e.to_dict() for e in sess.timeline()]
    # file is valid JSONL
    with open(path) as f:
        lines = [json.loads(l) for l in f]
    assert len(lines) == 2 and lines[0]["meta"] == {"mode": "x"}


def test_custom_sink_receives_every_event():
    sink = RingBufferSink(maxlen=100)
    with TraceSession("sinks", sinks=[sink]) as sess:
        sess.emit("progress", "p")
        sess.emit("dispatch", "d")
    assert [e.name for e in sink.events()] == ["p", "d"]


# -- ambient activation (contextvars) ---------------------------------------

def test_ambient_session_install_and_teardown():
    assert current_session() is None
    with TraceSession("outer") as outer:
        assert current_session() is outer
        with TraceSession("inner") as inner:
            assert current_session() is inner
        assert current_session() is outer
    assert current_session() is None


def test_tracker_created_before_session_reports_into_it():
    tracker = DoorbellTracker()          # armed before any session exists
    with TraceSession("late") as sess:
        tracker.ring("late_ring", payload=7)
    assert tracker.count == 1
    evs = sess.timeline(kinds="dispatch")
    assert len(evs) == 1 and evs[0].name == "late_ring"
    assert evs[0].payload_bytes == 7
    # outside the block, the same tracker is silent again
    tracker.ring("after")
    assert sess.n_events == 1


def test_explicit_injection_wins_over_ambient():
    mine = TraceSession("mine")
    tracker = DoorbellTracker(session=mine)
    with TraceSession("ambient") as amb:
        tracker.ring("ding")
    assert len(mine.timeline()) == 1
    assert len(amb.timeline()) == 0


# -- legacy standalone entry points record identically -----------------------

def test_doorbell_standalone_records_identically():
    def run_one():
        t = DoorbellTracker()
        wrapped = t.wrap(lambda x: x + 1, "inc", block=True)
        wrapped(jnp.ones(4))
        t.ring("manual", payload=3)
        return t

    bare = run_one()
    with TraceSession("wrapped"):
        inside = run_one()
    for a, b in zip(bare.records, inside.records):
        assert (a.seq, a.name, a.payload_bytes) == \
            (b.seq, b.name, b.payload_bytes)
    assert bare.summary()["by_name"].keys() == \
        inside.summary()["by_name"].keys()
    assert bare.count == inside.count == 2


def test_capture_standalone_records_identically():
    def run_one():
        cap = CommandStreamCapture()
        return cap.lower_and_compile("g", lambda x: x @ x,
                                     args=(jnp.ones((4, 4)),))

    bare = run_one()
    with TraceSession("wrapped") as sess:
        inside = run_one()
    assert bare.name == inside.name == "g"
    assert bare.n_ops == inside.n_ops
    assert bare.command_bytes == inside.command_bytes
    assert [e.kind for e in sess.timeline()] == ["compile"]


def test_wrap_preserves_function_metadata():
    def my_dispatch(x):
        """docstring survives wrapping"""
        return x

    t = DoorbellTracker()
    wrapped = t.wrap(my_dispatch, "d")
    assert wrapped.__name__ == "my_dispatch"
    assert wrapped.__doc__ == "docstring survives wrapping"


def test_hybrid_mover_and_progress_legacy_paths():
    mover = HybridMover(threshold=1024)
    _, rec = mover.put(np.zeros(16, np.float32))
    assert rec.mode == "inline" and mover.stats()["inline"] == 1
    pt = ProgressTracker()
    tok = pt.release(jnp.ones(2))
    pt.wait(tok)
    assert tok.completed


# -- one session drives trainer AND server (acceptance criterion) -----------

def test_one_session_drives_trainer_and_server():
    from repro.configs import SMOKE_ARCHS
    from repro.configs.shapes import ShapeConfig
    from repro.runtime.server import Request, Server
    from repro.runtime.trainer import Trainer

    cfg = SMOKE_ARCHS["deepseek-7b"]
    shape = ShapeConfig("tiny", 64, 4, "train")
    sess = TraceSession("prod")
    tr = Trainer(cfg, shape, steps_per_launch=2, session=sess)
    out = tr.train(2)
    srv = Server(cfg, batch_size=2, max_seq=64, session=sess)
    o = srv.serve([Request(0, np.arange(4, dtype=np.int32),
                           max_new_tokens=4)])
    assert tr.session is srv.session is sess
    assert out["doorbells"] == 1 and o["doorbells"] >= 2
    evs = sess.timeline()
    assert [e.seq for e in evs] == list(range(len(evs)))
    names = {e.name for e in evs}
    assert "train_k_steps" in names          # trainer dispatch
    assert "prefill" in names                # server dispatch
    kinds = {e.kind for e in evs}
    assert {"dispatch", "progress"} <= kinds


# -- summary / report -------------------------------------------------------

def test_summary_is_json_serializable_and_counts_by_kind():
    with TraceSession("summ") as sess:
        sess.mover.put(np.zeros(4, np.float32))
        sess.emit("dispatch", "d", payload_bytes=10)
        sess.emit("dispatch", "d", payload_bytes=5)
    s = sess.summary()
    json.dumps(s)               # must not raise
    assert s["by_kind"] == {"transfer": 1, "dispatch": 2}
    assert s["by_name"]["d"]["events"] == 2
    assert s["by_name"]["d"]["payload_bytes"] == 15


def test_report_interleaves_all_kinds_in_submission_order():
    with TraceSession("rep") as sess:
        cs = sess.capture.lower_and_compile("h", lambda x: x - 1,
                                            args=(jnp.ones(2),))
        sess.wrap(cs.compiled, "h_disp")(jnp.ones(2))
        sess.mover.put(np.zeros(2, np.float32))
    assert [e.kind for e in sess.timeline()] == \
        ["compile", "dispatch", "transfer"]
    text = sess.report()
    event_lines = [l for l in text.splitlines()
                   if l.strip()[:1].isdigit() and "ms" in l]
    assert [l.split()[2] for l in event_lines] == \
        ["compile", "dispatch", "transfer"]
    assert "TRACE SESSION rep" in text


def test_empty_session_summary_is_wellformed_and_zeroed():
    """Regression: an untouched session must return the full documented
    schema with zeros, not whatever falls out of empty accumulators."""
    s = TraceSession("empty").summary()
    json.dumps(s)               # serializable
    kinds = {"compile", "dispatch", "transfer", "graph_launch", "progress"}
    assert s["events"] == 0 and s["dropped"] == 0
    assert s["by_kind"] == {k: 0 for k in kinds}
    assert s["dur_s_by_kind"] == {k: 0.0 for k in kinds}
    assert s["payload_by_kind"] == {k: 0 for k in kinds}
    assert s["by_name"] == {}
    assert s["total_payload_bytes"] == 0
    assert s["total_dispatch_s"] == 0.0
    assert s["wall_s"] >= 0.0
    assert s["session"] == "empty"
    # after the first event the per-kind maps track only what was seen
    sess = TraceSession("one")
    sess.emit("dispatch", "d")
    assert sess.summary()["by_kind"] == {"dispatch": 1}


def test_session_tags_land_in_every_event_meta():
    with TraceSession("tagged", tags={"host": "h0", "process": 3}) as sess:
        sess.emit("dispatch", "d")
        sess.emit("transfer", "t", mode="inline")   # explicit meta merges
        sess.emit("progress", "p", process=9)       # explicit wins
    evs = sess.timeline()
    assert all(e.meta["host"] == "h0" for e in evs)
    assert evs[0].meta["process"] == 3
    assert evs[1].meta == {"host": "h0", "process": 3, "mode": "inline"}
    assert evs[2].meta["process"] == 9


def test_session_barrier_emits_alignment_event():
    with TraceSession("b") as sess:
        ev = sess.barrier("sync-1")
    assert ev.kind == "progress" and ev.name == "obs.barrier"
    assert ev.meta["barrier"] == "sync-1"
    assert isinstance(ev.meta["wall"], float)


def test_sink_stats_one_entry_per_sink(tmp_path):
    class Bare:                 # sink without stats()
        def emit(self, e):
            pass

    path = str(tmp_path / "t.jsonl")
    sess = TraceSession("stats", jsonl_path=path, sinks=[Bare()])
    sess.emit("dispatch", "d")
    stats = sess.sink_stats()
    assert [s["sink"] for s in stats] == \
        ["RingBufferSink", "JsonlSink", "Bare"]
    assert stats[0]["emitted"] == 1
    assert stats[1]["written"] == 1


def test_add_and_remove_sink_midflight():
    sess = TraceSession("dyn")
    sess.emit("dispatch", "before")
    late = RingBufferSink()
    sess.add_sink(late)
    sess.emit("dispatch", "during")
    sess.remove_sink(late)
    sess.emit("dispatch", "after")
    assert [e.name for e in late.events()] == ["during"]


# -- thread safety ----------------------------------------------------------

def test_ring_buffer_sink_thread_safe_counts():
    """Satellite: drop-count updates must be exact when one ring is shared
    by several sessions emitting concurrently."""
    import threading

    ring = RingBufferSink(maxlen=64)
    sessions = [TraceSession(f"s{i}", sinks=[ring]) for i in range(4)]

    def pump(sess):
        for _ in range(500):
            sess.emit("progress", "p")

    threads = [threading.Thread(target=pump, args=(s,)) for s in sessions]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert ring.n_emitted == 2000
    assert len(ring) == 64
    assert ring.dropped == 2000 - 64
    st = ring.stats()
    assert st["emitted"] == 2000 and st["dropped"] == 2000 - 64


def test_emit_thread_safe_seq_and_jsonl(tmp_path):
    """A traffic thread and a decode loop share one session: sequence
    numbers stay unique/contiguous and the lazily-opened JSONL sink never
    double-opens or interleaves lines."""
    import threading

    path = tmp_path / "threads.jsonl"
    n_threads, per_thread = 8, 50
    with TraceSession("mt", jsonl_path=str(path)) as sess:
        barrier = threading.Barrier(n_threads)

        def worker(tid):
            barrier.wait()      # maximize interleaving incl. the lazy open
            for i in range(per_thread):
                sess.emit("progress", f"w{tid}", payload_bytes=1)

        threads = [threading.Thread(target=worker, args=(t,))
                   for t in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    total = n_threads * per_thread
    assert sess.n_events == total
    seqs = [e.seq for e in sess.timeline()]
    assert seqs == list(range(total))               # unique AND contiguous
    loaded = JsonlSink.load(str(path))              # every line parses
    assert len(loaded) == total
    assert sorted(e.seq for e in loaded) == list(range(total))
    s = sess.summary()
    assert s["by_kind"]["progress"] == total
    assert s["total_payload_bytes"] == total


def test_jsonl_sink_shared_across_sessions_single_file_handle(tmp_path):
    """One sink instance fed by two sessions concurrently stays consistent."""
    import threading

    path = tmp_path / "shared.jsonl"
    sink = JsonlSink(str(path))
    a = TraceSession("a", sinks=[sink])
    b = TraceSession("b", sinks=[sink])

    def pump(sess):
        for _ in range(100):
            sess.emit("dispatch", "x")

    ts = [threading.Thread(target=pump, args=(s,)) for s in (a, b)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    sink.close()
    assert len(JsonlSink.load(str(path))) == 200


def test_jsonl_load_skips_hand_truncated_trailing_line(tmp_path):
    """A shard whose last line was cut mid-write (killed process) still
    loads: every complete line parses, the partial one is skipped with a
    warning.  Corruption *before* valid lines is a broken file and raises.
    """
    path = tmp_path / "crashed.jsonl"
    with TraceSession("victim", jsonl_path=str(path)) as sess:
        for i in range(5):
            sess.emit("dispatch", f"d{i}", payload_bytes=8 * i)
    full = path.read_text()
    lines = full.splitlines(keepends=True)
    # chop the final record in half, as a SIGKILL mid-fwrite would
    path.write_text("".join(lines[:-1]) + lines[-1][: len(lines[-1]) // 2])

    with pytest.warns(RuntimeWarning, match="truncated trailing line"):
        loaded = JsonlSink.load(str(path))
    assert [e.name for e in loaded] == [f"d{i}" for i in range(4)]
    assert all(e.payload_bytes == 8 * e.seq for e in loaded)

    # same half-line *followed by* valid records is not a crash artifact
    path.write_text("".join(lines[:3])
                    + lines[3][: len(lines[3]) // 2] + "\n" + lines[4])
    with pytest.raises((json.JSONDecodeError, KeyError, ValueError)):
        JsonlSink.load(str(path))
