"""Command-stream parser: trip counts, collectives, footprint."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import capture_fn, parse_hlo
from repro.core.hlo import _link_bytes, _group_size


def test_scan_trip_count_weighting():
    W = jnp.zeros((64, 64), jnp.float32)

    def f(x):
        def body(c, _):
            return jnp.tanh(c @ W), ()
        y, _ = jax.lax.scan(body, x, None, length=7)
        return y

    cs = capture_fn(f, jax.ShapeDtypeStruct((8, 64), jnp.float32))
    # 7 iterations x 2*8*64*64 flops; cost_analysis reports body once
    expect = 7 * 2 * 8 * 64 * 64
    assert cs.flops == pytest.approx(expect, rel=0.15)
    assert cs.xla_flops == pytest.approx(expect / 7, rel=0.15)
    assert not cs.stream.unknown_trip_counts


def test_unrolled_matches_scan_flops():
    W = jnp.zeros((32, 32), jnp.float32)

    def scan_f(x):
        y, _ = jax.lax.scan(lambda c, _: (c @ W, ()), x, None, length=5)
        return y

    def unroll_f(x):
        for _ in range(5):
            x = x @ W
        return x

    a = capture_fn(scan_f, jax.ShapeDtypeStruct((4, 32), jnp.float32))
    b = capture_fn(unroll_f, jax.ShapeDtypeStruct((4, 32), jnp.float32))
    assert a.flops == pytest.approx(b.flops, rel=0.05)


def test_link_bytes_accounting():
    # all-gather: receive (n-1)/n of the gathered buffer
    assert _link_bytes("all-gather", 1024, 256, 4) == 768
    # all-reduce: ring = 2x operand x (n-1)/n
    assert _link_bytes("all-reduce", 256, 256, 4) == 384
    # reduce-scatter: send (n-1)/n of the operand
    assert _link_bytes("reduce-scatter", 256, 1024, 4) == 768
    assert _link_bytes("collective-permute", 256, 256, 4) == 256
    assert _link_bytes("all-reduce", 256, 256, 1) == 0


def test_group_size_parsing():
    assert _group_size("replica_groups={{0,1,2,3},{4,5,6,7}}") == 4
    assert _group_size("replica_groups=[4,2]<=[2,4]T(1,0)") == 2
    assert _group_size("replica_groups=[2,16]<=[32]") == 16
    assert _group_size("no groups here") == 1


def test_footprint_nonzero_and_entries_decoded():
    def f(x):
        return jnp.sum(x * 2.0)

    cs = capture_fn(f, jax.ShapeDtypeStruct((128,), jnp.float32))
    assert cs.command_bytes > 0
    assert cs.n_ops >= 1
    assert all(e.opcode for e in cs.stream.entries)


def test_dus_inplace_accounting():
    """DUS into a big buffer must charge slice-size, not buffer-size."""
    def f(buf, upd):
        def body(c, i):
            return jax.lax.dynamic_update_slice(c, upd, (i, 0)), ()
        y, _ = jax.lax.scan(body, buf, jnp.arange(64))
        return y

    cs = capture_fn(f, jax.ShapeDtypeStruct((64, 256), jnp.float32),
                    jax.ShapeDtypeStruct((1, 256), jnp.float32))
    # naive accounting would be 64 iters x 2 x 64x256x4B = 8.4 MB
    assert cs.memory_bytes < 3_000_000
