"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; only launch/dryrun.py forces 512 devices."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


@pytest.fixture(autouse=True)
def _isolated_policies(monkeypatch, tmp_path):
    """Tuned policies auto-apply by default (repro.tune); tests must not be
    steered by whatever happens to live in results/policies — each test gets
    an empty policy dir and a clean ambient policy."""
    from repro.tune.policy import clear_active_policy
    monkeypatch.setenv("REPRO_POLICY_DIR", str(tmp_path / "policies"))
    clear_active_policy()
    yield
    clear_active_policy()
