"""Continuous-batching engine: equivalence, admission/eviction, traffic.

The load-bearing invariant: because every KV slot carries a complete
batch-1 decode state (own cache length, own greedy chain), a request's
tokens are independent of batch composition and join time — continuous
batching must produce *exactly* the tokens a one-shot ``serve()`` of the
same request would.
"""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKE_ARCHS
from repro.runtime.scheduler import (AdmissionQueue, RequestTicket,
                                     percentile)
from repro.runtime.server import ContinuousBatchingServer, Request, Server
from repro.runtime.traffic import TrafficSpec, generate, replay

CFG = SMOKE_ARCHS["gemma-2b"]


def mk_request(uid, plen, budget, seed=7):
    rng = np.random.default_rng(seed + uid)
    return Request(uid, rng.integers(0, CFG.vocab_size,
                                     size=plen).astype(np.int32),
                   max_new_tokens=budget)


@pytest.fixture(scope="module")
def engine():
    return ContinuousBatchingServer(CFG, batch_size=2, max_seq=32,
                                    tokens_per_launch=3, seed=1,
                                    max_pending=8)


@pytest.fixture(scope="module")
def solo():
    """One-shot single-request reference decoder (same params: same seed)."""
    return Server(CFG, batch_size=1, max_seq=32, tokens_per_launch=1, seed=1)


# -- serve() bugfixes -------------------------------------------------------

def test_serve_empty_batch_returns_wellformed_metrics(solo):
    out = solo.serve([])
    assert out == {"wall_s": 0.0, "doorbells": 0, "new_tokens": 0,
                   "tokens_per_doorbell": 0.0, "trace_events": 0}


def test_serve_overfull_batch_raises_valueerror_not_assert(solo):
    reqs = [mk_request(i, 4, 2) for i in range(2)]    # batch_size is 1
    with pytest.raises(ValueError, match="batch_size"):
        solo.serve(reqs)


def test_decode_block_truncated_continuation_token(solo):
    """Regression: a truncated block (want < T) must hand back the last
    *kept* token ``tok_block[take-1]`` as its continuation, not
    ``tok_block[-1]`` — the scanned-past token belongs to a speculative
    suffix the caller never accepted, so any downstream use of the
    continuation (streaming, stop-sequence checks) would fork the chain."""
    srv = Server(CFG, batch_size=1, max_seq=32, tokens_per_launch=3, seed=1)
    # this uid/plen is chosen so the scanned-past token differs *by value*
    # from the last kept one — a degenerate constant greedy chain (most
    # random prompts on the smoke config) would mask the bug
    r = mk_request(9, 4, 1)
    toks = np.asarray(r.prompt)[None, :]
    state, logits = srv._prefill(srv.params, jnp.asarray(toks))
    nxt = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
    first = int(nxt[0, 0])
    state, block, nxt = srv._decode_block(state, nxt, want=2)
    assert len(block) == 2
    # continuation == last kept token, not the scanned-past one
    assert int(nxt[0, 0]) == int(block[-1][0])
    # and the kept prefix is the exact uninterrupted greedy chain
    ref = mk_request(9, 4, 3)          # same uid/seed -> same prompt
    solo.serve([ref])
    assert [first] + [int(b[0]) for b in block] == ref.tokens


# -- continuous batching ----------------------------------------------------

def test_continuous_tokens_equal_oneshot_per_request(engine, solo):
    """5 requests through 2 slots: joins/leaves mid-decode, heterogeneous
    prompt lengths and budgets — every token stream identical to a solo
    one-shot serve of the same request."""
    shapes = [(3, 4), (5, 7), (8, 5), (3, 7), (5, 4)]
    reqs = [mk_request(i, p, b) for i, (p, b) in enumerate(shapes)]
    tickets = [engine.submit(r) for r in reqs]
    out = engine.run(idle_timeout_s=0.0)
    assert out["completed"] == 5 and out["evicted"] == 0
    assert out["new_tokens"] == sum(b for _, b in shapes)
    assert out["doorbells"] > 0
    assert out["tokens_per_doorbell"] == pytest.approx(
        out["new_tokens"] / out["doorbells"])
    for r, t in zip(reqs, tickets):
        assert t.status == "done"
        assert len(t.tokens) == r.max_new_tokens
        ref = Request(r.uid, r.prompt, max_new_tokens=r.max_new_tokens)
        solo.serve([ref])
        assert t.tokens == ref.tokens, f"uid={r.uid} diverged"
        assert r.tokens == t.tokens          # mirrored onto the Request


def test_continuous_decode_launch_shape_stable_across_churn(engine):
    """Join/leave churn must reuse the same compiled multi-token decode:
    jitted launches are keyed by shape, and slot membership never changes
    the stacked state's shape."""
    n_compiles = engine._decode_slots.__wrapped__._cache_size()
    tix = [engine.submit(mk_request(100 + i, 3, 2)) for i in range(3)]
    engine.run(idle_timeout_s=0.0)
    assert all(t.status == "done" for t in tix)
    assert engine._decode_slots.__wrapped__._cache_size() == n_compiles


def test_admission_rejects_when_queue_full(engine):
    """max_pending=8 with policy=reject: overflow submits are refused but
    everything admitted still completes."""
    tix = [engine.submit(mk_request(200 + i, 3, 2)) for i in range(11)]
    rejected = [t for t in tix if t.status == "rejected"]
    assert len(rejected) == 3
    assert all(t.reason == "queue_full" for t in rejected)
    out = engine.run(idle_timeout_s=0.0)
    assert out["completed"] == 8
    assert all(t.status in ("done", "rejected") for t in tix)


def test_admission_rejects_prompt_longer_than_max_seq(engine):
    t = engine.submit(mk_request(300, 33, 2))        # max_seq is 32
    assert t.status == "rejected" and t.reason == "prompt_exceeds_max_seq"
    assert engine.run(idle_timeout_s=0.0)["requests"] == 0


def test_eviction_on_kv_overrun_truncates_to_capacity():
    eng = ContinuousBatchingServer(CFG, batch_size=2, max_seq=8,
                                   tokens_per_launch=2, seed=1)
    ok = eng.submit(mk_request(0, 4, 3))             # fits: cap=5
    greedy = eng.submit(mk_request(1, 6, 10))        # cap = 8-6+1 = 3
    out = eng.run(idle_timeout_s=0.0)
    assert ok.status == "done" and len(ok.tokens) == 3
    assert greedy.status == "evicted" and greedy.reason == "kv_overrun"
    assert len(greedy.tokens) == 3
    assert out["completed"] == 1 and out["evicted"] == 1
    # the served prefix is still the exact greedy chain
    solo = Server(CFG, batch_size=1, max_seq=8, tokens_per_launch=1, seed=1)
    ref = Request(1, greedy.request.prompt, max_new_tokens=3)
    solo.serve([ref])
    assert greedy.tokens == ref.tokens


def test_threaded_replay_requests_join_running_decode():
    """Realtime replay: a producer thread submits Poisson arrivals while
    the decode loop runs; everything lands on one session timeline."""
    eng = ContinuousBatchingServer(CFG, batch_size=2, max_seq=16,
                                   tokens_per_launch=2, seed=1)
    # warm up compiles so arrival pacing isn't swamped by the first launch
    eng.submit(mk_request(999, 4, 2))
    eng.run(idle_timeout_s=0.0)
    spec = TrafficSpec(n_requests=8, rate=400.0, prompt_lens=(4,),
                       new_tokens=(3, 5), seed=3)
    tickets, out = replay(eng, generate(spec, CFG.vocab_size),
                          realtime=True, idle_timeout_s=10.0)
    assert len(tickets) == 8
    assert out["completed"] == 8
    assert out["latency_p99_s"] >= out["latency_p50_s"] >= 0.0
    names = {e.name for e in eng.session.timeline(kinds="progress")}
    assert {"serve.submit", "serve.admit", "serve.finish"} <= names
    # intake closed by the replay harness once the producer drained
    assert eng.queue.closed


# -- scheduler unit tests (no JAX) ------------------------------------------

def test_admission_queue_drop_oldest_policy():
    q = AdmissionQueue(max_pending=2, policy="drop_oldest")
    t = [RequestTicket(request=mk_request(i, 2, 1)) for i in range(3)]
    assert q.submit(t[0]) == (True, None)
    assert q.submit(t[1]) == (True, None)
    accepted, dropped = q.submit(t[2])
    assert accepted and dropped is t[0]
    assert q.pop() is t[1] and q.pop() is t[2] and q.pop() is None
    assert q.n_dropped == 1


def test_admission_queue_close_refuses_and_unknown_policy_raises():
    q = AdmissionQueue(max_pending=2, policy="reject")
    q.close()
    assert q.submit(RequestTicket(request=mk_request(0, 2, 1))) == (False,
                                                                    None)
    assert q.n_refused == 1
    with pytest.raises(ValueError, match="policy"):
        AdmissionQueue(policy="lifo")


def test_percentile_interpolation():
    xs = [0.0, 1.0, 2.0, 3.0]
    assert percentile(xs, 50.0) == pytest.approx(1.5)
    assert percentile(xs, 99.0) == pytest.approx(2.97)
    assert percentile([5.0], 99.0) == 5.0
    assert percentile([], 50.0) == 0.0
    assert percentile([-1.0, 2.0], 50.0) == 2.0      # -1 = "never happened"


# -- traffic generator ------------------------------------------------------

def test_poisson_traffic_deterministic_per_seed():
    spec = TrafficSpec(n_requests=32, rate=100.0, prompt_lens=(4, 8),
                       new_tokens=(2, 6), seed=11)
    a = generate(spec, vocab_size=CFG.vocab_size)
    b = generate(spec, vocab_size=CFG.vocab_size)
    assert [x.t for x in a] == [x.t for x in b]
    assert all(np.array_equal(x.request.prompt, y.request.prompt)
               for x, y in zip(a, b))
    assert [x.request.max_new_tokens for x in a] == \
        [y.request.max_new_tokens for y in b]
    c = generate(TrafficSpec(n_requests=32, rate=100.0, prompt_lens=(4, 8),
                             new_tokens=(2, 6), seed=12), CFG.vocab_size)
    assert [x.t for x in a] != [x.t for x in c]
    # arrivals are ordered and lengths come from the declared choices
    ts = [x.t for x in a]
    assert ts == sorted(ts) and ts[0] > 0.0
    assert {len(x.request.prompt) for x in a} <= {4, 8}
    assert {x.request.max_new_tokens for x in a} <= {2, 6}
