"""Paged KV backend, chunked prefill, and pluggable scheduling.

The serving memory-path invariants:

* backend equivalence — the paged backend (block tables, page pool) and
  chunked prefill must produce *bit-identical* token streams to the dense
  whole-prompt path on the same seeded replay;
* capacity honesty — a too-small page pool evicts with
  ``reason="kv_pages"`` instead of corrupting neighbours;
* prefix sharing — prompts with a common prefix reuse the pages holding
  it, strictly shrinking the prefill command footprint;
* chunked prefill pacing — at most one bounded prefill launch is
  interleaved per decode iteration, so decode never stalls behind a long
  prompt.
"""
import threading
import time

import numpy as np
import pytest

from repro.configs import SMOKE_ARCHS
from repro.configs.shapes import SERVE_SHAPES, kv_geometry
from repro.core.session import SPAN_EVENT, TraceSession
from repro.runtime.kv import KV_BACKENDS, make_kv
from repro.runtime.scheduler import (AdmissionQueue, FairSharePolicy,
                                     PriorityPolicy, RequestTicket,
                                     make_policy)
from repro.runtime.server import ContinuousBatchingServer, Request
from repro.runtime.traffic import TrafficSpec, generate, replay

CFG = SMOKE_ARCHS["gemma-2b"]

SPEC = TrafficSpec(n_requests=10, rate=1000.0, prompt_lens=(4, 8, 16),
                   new_tokens=(4, 9), seed=3)


class ListSink:
    def __init__(self):
        self.events = []

    def emit(self, event):
        self.events.append(event)


def _replay(sink=None, **kw):
    sess = TraceSession(name="test_kv", sinks=[sink] if sink else None)
    eng = ContinuousBatchingServer(CFG, batch_size=4, max_seq=64,
                                   tokens_per_launch=4, seed=0,
                                   session=sess, **kw)
    tickets, metrics = replay(eng, generate(SPEC, CFG.vocab_size),
                              realtime=False)
    return {t.uid: list(t.tokens) for t in tickets}, metrics, eng


@pytest.fixture(scope="module")
def dense_ref():
    toks, metrics, _ = _replay()
    return toks, metrics


# -- geometry ---------------------------------------------------------------

def test_kv_geometry_and_serve_shapes():
    assert kv_geometry(64, 16, 4) == (4, 16)
    with pytest.raises(ValueError, match="multiple"):
        kv_geometry(64, 24, 4)
    with pytest.raises(ValueError, match="positive"):
        kv_geometry(64, 0, 4)
    for shape in SERVE_SHAPES.values():
        n_blk, pages = shape.geometry()
        assert n_blk * shape.kv_page_tokens == shape.max_seq
        assert pages == shape.slots * n_blk


def test_make_kv_rejects_unknown_backend():
    eng = object.__new__(ContinuousBatchingServer)   # no engine needed
    with pytest.raises(ValueError, match="backend"):
        make_kv(eng, "compressed")
    assert KV_BACKENDS == ("dense", "paged")


# -- backend equivalence ----------------------------------------------------

def test_paged_tokens_bit_identical_to_dense(dense_ref):
    toks, metrics, _ = _replay(kv="paged", kv_page_tokens=8)
    assert toks == dense_ref[0]
    assert metrics["kv"]["backend"] == "paged"
    # default pool holds every slot fully grown: exhaustion impossible
    assert metrics["kv"]["pages_total"] == 4 * (64 // 8)
    assert metrics["evicted"] == dense_ref[1]["evicted"]


def test_chunked_prefill_bit_identical_both_backends(dense_ref):
    d_toks, d_m, _ = _replay(prefill_chunk=4)
    p_toks, p_m, _ = _replay(kv="paged", kv_page_tokens=8, prefill_chunk=4)
    assert d_toks == dense_ref[0]
    assert p_toks == dense_ref[0]
    # prompts longer than the chunk really went through the chunked path
    assert d_m["kv"]["chunked_prompts"] > 0
    assert p_m["kv"]["chunked_prompts"] > 0
    assert d_m["kv"]["prefill_chunk_launches"] > 0


# -- page exhaustion --------------------------------------------------------

def test_page_exhaustion_evicts_with_kv_pages_reason():
    eng = ContinuousBatchingServer(CFG, batch_size=4, max_seq=64,
                                   tokens_per_launch=2, seed=0,
                                   kv="paged", kv_page_tokens=8, kv_pages=9)
    rng = np.random.default_rng(0)
    tix = [eng.submit(Request(uid=i,
                              prompt=rng.integers(0, CFG.vocab_size, 20)
                              .astype(np.int32),
                              max_new_tokens=30)) for i in range(4)]
    eng.run(idle_timeout_s=0.0)
    assert all(t.finished for t in tix)
    evicted = [t for t in tix if t.status == "evicted"]
    assert evicted and all(t.reason == "kv_pages" for t in evicted)
    # survivors ran to their full budget untouched by the eviction
    assert any(t.status == "done" and len(t.tokens) == 30 for t in tix)


def test_pool_smaller_than_one_slot_rejected():
    with pytest.raises(ValueError, match="full slot"):
        ContinuousBatchingServer(CFG, batch_size=4, max_seq=64,
                                 tokens_per_launch=2, seed=0, kv="paged",
                                 kv_page_tokens=8, kv_pages=4)


def test_explicit_zero_page_tokens_rejected():
    # an explicit 0 must reach kv_geometry's validation, not silently
    # coerce to the default
    with pytest.raises(ValueError, match="positive"):
        ContinuousBatchingServer(CFG, batch_size=1, max_seq=64,
                                 tokens_per_launch=2, seed=0,
                                 kv="paged", kv_page_tokens=0)


# -- shared-prefix page reuse -----------------------------------------------

def _shared_prefix_requests(n=8, prefix_len=24, suffix_len=8, budget=6):
    rng = np.random.default_rng(0)
    prefix = rng.integers(0, CFG.vocab_size, prefix_len).astype(np.int32)
    return [Request(uid=uid,
                    prompt=np.concatenate(
                        [prefix, rng.integers(0, CFG.vocab_size, suffix_len)
                         .astype(np.int32)]),
                    max_new_tokens=budget) for uid in range(n)]


def _run_shared(sink=None, **kw):
    sess = TraceSession(name="test_kv_shared",
                        sinks=[sink] if sink else None)
    eng = ContinuousBatchingServer(CFG, batch_size=4, max_seq=64,
                                   tokens_per_launch=2, seed=0,
                                   session=sess, **kw)
    tix = [eng.submit(r) for r in _shared_prefix_requests()]
    m = eng.run(idle_timeout_s=0.0)
    return {t.uid: list(t.tokens) for t in tix}, m


def test_shared_prefix_reuses_pages_and_shrinks_prefill():
    sink = ListSink()
    d_toks, d_m = _run_shared(prefill_chunk=8)
    p_toks, p_m = _run_shared(sink, kv="paged", kv_page_tokens=8,
                              prefill_chunk=8)
    assert p_toks == d_toks                       # reuse never changes bits
    kv = p_m["kv"]
    assert kv["prefix_hits"] > 0
    assert kv["pages_reused"] > 0
    # the satellite acceptance pair: strictly fewer prefill doorbells AND
    # strictly fewer prefill payload bytes than dense on the same workload
    assert kv["prefill_launches"] < d_m["kv"]["prefill_launches"]
    assert kv["prefill_payload_bytes"] < d_m["kv"]["prefill_payload_bytes"]
    names = [e.name for e in sink.events if e.kind == "progress"]
    assert names.count("kv.prefix_hit") == kv["prefix_hits"]
    assert "kv.alloc" in names and "kv.free" in names


def test_shared_prefix_pinned_under_pool_pressure():
    """Regression: shared prefix pages must be pinned *before* fresh pages
    are allocated.  A refcount-0 shared page sits in the reclaimable cache,
    and under pool pressure ``_take_pages`` used to reclaim it and hand it
    back as a prefill target for the very request attaching to it — the
    block table then held the same physical page twice and prefill clobbered
    the shared prefix."""
    eng = ContinuousBatchingServer(CFG, batch_size=1, max_seq=64,
                                   tokens_per_launch=2, seed=0,
                                   kv="paged", kv_page_tokens=8, kv_pages=8)
    rng = np.random.default_rng(7)
    prefix = rng.integers(0, CFG.vocab_size, 24).astype(np.int32)

    def mk(n):
        return rng.integers(0, CFG.vocab_size, n).astype(np.int32)

    # A fills the whole 8-page pool (4-page prompt + decode growth to the
    # cap), then releases: its 4 prompt pages stay cached, 4 go free
    eng.submit(Request(uid=0, prompt=np.concatenate([prefix, mk(8)]),
                       max_new_tokens=31))
    eng.run(idle_timeout_s=0.0)
    kv = eng.kv
    assert len(kv._cached) == 4 and len(kv._free) == 4

    # B shares the 3-page prefix and needs 5 fresh pages — one more than
    # the free list holds, forcing a reclaim from the cache while the
    # shared pages sit there at refcount 0
    b = RequestTicket(request=Request(
        uid=1, prompt=np.concatenate([prefix, mk(40)]), max_new_tokens=1))
    assert kv.begin(0, b)
    table = kv.tables[0, :int(kv.n_rows[0])].tolist()
    assert len(set(table)) == len(table)      # no physical page twice
    assert all(kv._ref[p] == 1 for p in table)
    assert kv.pages_reused == 3

    # rollback: with the free list exhausted and every reclaimable page
    # pinned as shared prefix, begin must fail AND undo its pins
    kv.release(0)
    kv._free.clear()
    c = RequestTicket(request=Request(
        uid=2, prompt=np.concatenate([prefix, mk(40)]), max_new_tokens=1))
    assert not kv.begin(0, c)
    assert len(kv._cached) == 3               # prefix pages reclaimable again
    assert all(kv._ref[p] == 0 for p in kv._cached)


def test_shared_prefix_under_pressure_tokens_match_dense():
    """End-to-end cover for the pin-before-allocate fix: prefix sharing and
    pool pressure *together* (each was covered separately before) must stay
    bit-identical to dense."""
    rng = np.random.default_rng(11)
    prefix = rng.integers(0, CFG.vocab_size, 24).astype(np.int32)

    def reqs():
        r = np.random.default_rng(13)

        def mk(n):
            return r.integers(0, CFG.vocab_size, n).astype(np.int32)

        return [Request(uid=0, prompt=np.concatenate([prefix, mk(8)]),
                        max_new_tokens=31),
                Request(uid=1, prompt=np.concatenate([prefix, mk(40)]),
                        max_new_tokens=1),
                Request(uid=2, prompt=np.concatenate([prefix, mk(8)]),
                        max_new_tokens=4)]

    def run(**kw):
        eng = ContinuousBatchingServer(CFG, batch_size=1, max_seq=64,
                                       tokens_per_launch=2, seed=0, **kw)
        tix = [eng.submit(r) for r in reqs()]
        eng.run(idle_timeout_s=0.0)
        return {t.uid: list(t.tokens) for t in tix}, eng

    d_toks, _ = run()
    p_toks, eng = run(kv="paged", kv_page_tokens=8, kv_pages=8)
    assert p_toks == d_toks
    assert eng.kv.prefix_hits == 2            # both followers attached
    assert all(t.status in ("done",) for t in eng.tickets)


def test_no_registration_in_clamped_decode_write_zone():
    """Pages overlapping [max_seq - T, max_seq) are never registered for
    sharing: a slot finishing at the KV cap scatter-writes its clamped
    decode rows there, and registered pages must stay immutable once other
    requests attach (reachable with page_tokens < tokens_per_launch)."""
    def run(**kw):
        eng = ContinuousBatchingServer(CFG, batch_size=1, max_seq=64,
                                       tokens_per_launch=8, seed=0, **kw)
        rng = np.random.default_rng(21)
        base = rng.integers(0, CFG.vocab_size, 60).astype(np.int32)
        ext = rng.integers(0, CFG.vocab_size, 4).astype(np.int32)
        tix = [eng.submit(Request(uid=0, prompt=base, max_new_tokens=5)),
               eng.submit(Request(uid=1, prompt=np.concatenate([base, ext]),
                                  max_new_tokens=1))]
        eng.run(idle_timeout_s=0.0)
        return {t.uid: list(t.tokens) for t in tix}, eng

    d_toks, _ = run()
    p_toks, eng = run(kv="paged", kv_page_tokens=4)
    assert p_toks == d_toks
    # A's 60-token prompt fully covers 15 pages, but page 14 spans
    # [56, 60) inside the clamp zone [56, 64) — only 14 get registered
    assert len(eng.kv._key_of) == 14
    # the follower still shares all 14 safe pages
    assert eng.kv.prefix_hits == 1 and eng.kv.pages_reused == 14


def test_traffic_prefix_len_prepends_shared_prefix():
    spec = TrafficSpec(n_requests=4, rate=100.0, prompt_lens=(4,),
                       new_tokens=(4,), seed=5, prefix_len=12)
    arrivals = generate(spec, vocab_size=CFG.vocab_size)
    prompts = [a.request.prompt for a in arrivals]
    assert all(len(p) == 16 for p in prompts)
    head = prompts[0][:12]
    assert all(np.array_equal(p[:12], head) for p in prompts)
    suffixes = {tuple(p[12:]) for p in prompts}
    assert len(suffixes) > 1                      # suffixes stay distinct


# -- chunked prefill pacing -------------------------------------------------

def test_chunked_prefill_bounds_launch_size_and_interleaves():
    sink = ListSink()
    sess = TraceSession(name="test_chunk", sinks=[sink])
    eng = ContinuousBatchingServer(CFG, batch_size=2, max_seq=64,
                                   tokens_per_launch=4, seed=0,
                                   session=sess, prefill_chunk=4)
    rng = np.random.default_rng(1)
    reqs = [Request(uid=i, prompt=rng.integers(0, CFG.vocab_size, 17)
                    .astype(np.int32), max_new_tokens=8) for i in range(3)]
    tix = [eng.submit(r) for r in reqs]
    eng.run(idle_timeout_s=0.0)
    assert all(t.status == "done" and len(t.tokens) == 8 for t in tix)
    spans = [e for e in sink.events if e.name == SPAN_EVENT]
    chunk_spans = [e for e in spans
                   if e.meta.get("span") == "serve.prefill_chunk"]
    assert chunk_spans, "chunked prompts must emit serve.prefill_chunk"
    assert all(e.meta["size"] <= 4 for e in chunk_spans)
    # per-prompt chunk count is ceil(17/4); launches are accounted on the
    # ticket so doorbell attribution still adds up per request
    assert all(t.n_prefill_launches == 5 for t in tix)
    # interleaving: between two chunk launches of the same prompt there is
    # at least one decode-iter span once any slot is decodable
    decode_ends = [e.t for e in spans
                   if e.meta.get("span") == "serve.decode_iter"]
    assert decode_ends, "decode proceeded while prompts were prefilling"


def test_chunked_prefill_keeps_decode_iters_flowing():
    """Span-profile acceptance: while decode-ready work exists, no gap
    between consecutive decode iterations exceeds 2x the median
    decode-iter duration (plus a small host-jitter floor for CI runners)
    — a 32-token prompt joining the batch never stalls it.

    One long-budget request with a short (un-chunked) prompt pins slot 0
    so decode work is continuously present; chunked 32-token prompts
    stream through slot 1.  Gaps are measured only while the pinned
    decoder is active (once every slot is mid-prefill there is legitimately
    nothing to decode)."""
    sess = TraceSession(name="test_gap")
    eng = ContinuousBatchingServer(CFG, batch_size=2, max_seq=64,
                                   tokens_per_launch=4, seed=0,
                                   session=sess, prefill_chunk=4)
    rng = np.random.default_rng(2)

    def workload(uid0):
        pin = Request(uid=uid0, prompt=rng.integers(0, CFG.vocab_size, 4)
                      .astype(np.int32), max_new_tokens=60)
        chunked = [Request(uid=uid0 + 1 + i,
                           prompt=rng.integers(0, CFG.vocab_size, 32)
                           .astype(np.int32), max_new_tokens=12)
                   for i in range(2)]
        return [pin] + chunked

    # warm run compiles the prefill/extend/decode kernels
    for r in workload(0):
        eng.submit(r)
    eng.run(idle_timeout_s=0.0)

    sink = ListSink()
    eng.session.add_sink(sink)
    tix = [eng.submit(r) for r in workload(100)]
    eng.run(idle_timeout_s=0.0)
    assert tix[0].status == "done"
    cutoff = tix[0].t_done - sess.t0     # span times are session-relative
    iters = [e for e in sink.events if e.name == SPAN_EVENT
             and e.meta.get("span") == "serve.decode_iter"
             and e.t <= cutoff]
    assert len(iters) >= 8               # chunk launches rode these gaps
    durs = sorted(e.dur_s for e in iters)
    median = durs[len(durs) // 2]
    gaps = [b.t - b.dur_s - a.t for a, b in zip(iters, iters[1:])]
    floor = 0.002                       # 2ms host jitter allowance
    assert max(gaps) <= 2.0 * median + floor, (
        f"decode stalled: max gap {max(gaps)*1e3:.2f}ms vs median iter "
        f"{median*1e3:.2f}ms")


# -- scheduling policies ----------------------------------------------------

def _tickets(*specs):
    """specs: (uid, priority, user, budget)."""
    out = []
    for uid, prio, user, budget in specs:
        r = Request(uid=uid, prompt=np.zeros(4, np.int32),
                    max_new_tokens=budget, priority=prio, user=user)
        out.append(RequestTicket(request=r))
    return out


def test_priority_policy_admits_highest_first():
    q = AdmissionQueue(max_pending=8)
    for t in _tickets((0, 0, "", 4), (1, 5, "", 4), (2, 5, "", 4),
                      (3, 1, "", 4)):
        q.submit(t)
    pol = PriorityPolicy()
    order = [q.pop(pol).uid for _ in range(4)]
    assert order == [1, 2, 3, 0]        # FIFO among the two priority-5s


def test_fair_share_policy_balances_users():
    q = AdmissionQueue(max_pending=8)
    for t in _tickets((0, 0, "a", 100), (1, 0, "a", 100),
                      (2, 0, "b", 1), (3, 0, "b", 1)):
        q.submit(t)
    pol = FairSharePolicy()
    order = [q.pop(pol).uid for _ in range(4)]
    # after user a's 100-token request, user b is least-served until its
    # cumulative budget catches up — so b gets both small requests next
    assert order == [0, 2, 3, 1]


def test_fair_share_reconciles_actual_tokens_on_finish():
    pol = FairSharePolicy()
    (t,) = _tickets((0, 0, "a", 100))
    pol.note_admitted(t)
    assert pol._served["a"] == 100      # budget charged up front
    t.tokens = [7, 7, 7]                # evicted after only 3 real tokens
    pol.note_finished(t)
    assert pol._served["a"] == 3        # reconciled to actual usage
    (u,) = _tickets((1, 0, "b", 5))
    pol.note_finished(u)                # never admitted: no-op
    assert "b" not in pol._served


def test_fair_share_ledger_bounded():
    pol = FairSharePolicy(max_users=2)
    for t in _tickets(*[(i, 0, f"u{i}", 1) for i in range(5)]):
        pol.note_admitted(t)
        t.tokens = [1]
        pol.note_finished(t)
    assert len(pol._served) <= 2        # churny users don't grow state
    assert not pol._inflight


def test_make_policy_names_and_unknown():
    for name in ("fifo", "priority", "fair"):
        assert make_policy(name).name == name
    with pytest.raises(ValueError, match="policy"):
        make_policy("sjf")


def test_peek_matches_pop_and_keeps_queue():
    q = AdmissionQueue(max_pending=8)
    for t in _tickets((0, 0, "", 4), (1, 7, "", 4)):
        q.submit(t)
    pol = PriorityPolicy()
    assert q.peek(pol).uid == 1
    assert len(q) == 2                  # peek never removes
    assert q.pop(pol).uid == 1
    assert q.peek().uid == 0            # default FIFO peek


def test_pop_policy_keeps_drop_oldest_semantics():
    q = AdmissionQueue(max_pending=2, policy="drop_oldest")
    ts = _tickets((0, 9, "", 4), (1, 0, "", 4), (2, 0, "", 4))
    q.submit(ts[0])
    q.submit(ts[1])
    ok, dropped = q.submit(ts[2])
    assert ok and dropped is ts[0]      # overflow drops the OLDEST queued
    assert q.n_dropped == 1             # regardless of its priority
    assert q.pop(PriorityPolicy()).uid == 1


def test_engine_priority_scheduling_end_to_end():
    eng = ContinuousBatchingServer(CFG, batch_size=1, max_seq=32,
                                   tokens_per_launch=2, seed=0,
                                   sched="priority")
    rng = np.random.default_rng(3)
    reqs = [Request(uid=i, prompt=rng.integers(0, CFG.vocab_size, 4)
                    .astype(np.int32), max_new_tokens=3, priority=i)
            for i in range(4)]
    tix = [eng.submit(r) for r in reqs]
    eng.run(idle_timeout_s=0.0)
    admits = sorted(tix, key=lambda t: t.t_admit)
    assert [t.uid for t in admits] == [3, 2, 1, 0]


# -- admission-queue condition variable -------------------------------------

def test_wait_for_work_wakes_on_submit():
    q = AdmissionQueue(max_pending=4)
    (t,) = _tickets((0, 0, "", 4))

    def late_submit():
        time.sleep(0.05)
        q.submit(t)

    threading.Thread(target=late_submit, daemon=True).start()
    t0 = time.perf_counter()
    assert q.wait_for_work(timeout=5.0)
    assert time.perf_counter() - t0 < 2.0   # woke on notify, not timeout


def test_wait_for_work_times_out_empty():
    q = AdmissionQueue(max_pending=4)
    t0 = time.perf_counter()
    assert not q.wait_for_work(timeout=0.05)
    assert time.perf_counter() - t0 >= 0.04


def test_wait_for_work_wakes_on_close():
    q = AdmissionQueue(max_pending=4)

    def late_close():
        time.sleep(0.05)
        q.close()

    threading.Thread(target=late_close, daemon=True).start()
    assert q.wait_for_work(timeout=5.0)
    assert q.closed
