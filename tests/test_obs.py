"""repro.obs: async/sampling sinks, fleet aggregation, live streaming."""
import json
import random
import threading
import time

import pytest

from repro.core import JsonlSink, RingBufferSink, TraceSession
from repro.obs import (AsyncSink, LiveServer, LiveSummary, SamplingSink,
                       aggregate, summarize)


# -- AsyncSink ---------------------------------------------------------------

def test_async_sink_forwards_everything_when_not_overrun():
    ring = RingBufferSink(maxlen=100000)
    a = AsyncSink(ring, maxsize=100000)
    sess = TraceSession("async", sinks=[a])
    for i in range(500):
        sess.emit("dispatch", f"d{i}", payload_bytes=1)
    a.close()
    st = a.stats()
    assert st["offered"] == 500
    assert st["dropped"] == 0
    assert st["forwarded"] == st["enqueued"] == 500
    assert len(ring.events()) == 500
    # forwarded events are the same objects, in enqueue order
    assert [e.name for e in ring.events()][:3] == ["d0", "d1", "d2"]


def test_async_sink_threaded_storm_exact_accounting():
    """Acceptance: a threaded emit storm loses no event unaccounted —
    offered == enqueued + dropped always, forwarded == enqueued after
    close, and the backend saw exactly the forwarded count."""
    ring = RingBufferSink(maxlen=1 << 20)
    a = AsyncSink(ring, maxsize=64)          # tiny queue: force drops
    sess = TraceSession("storm", sinks=[a])
    n_threads, per_thread = 8, 500
    barrier = threading.Barrier(n_threads)

    def worker(tid):
        barrier.wait()
        for i in range(per_thread):
            sess.emit("progress", f"w{tid}")

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not any(t.is_alive() for t in threads)      # no deadlock
    a.close(timeout_s=30)
    st = a.stats()
    total = n_threads * per_thread
    assert st["offered"] == total
    assert st["enqueued"] + st["dropped"] == total
    assert st["forwarded"] == st["enqueued"]
    assert st["pending"] == 0
    assert ring.stats()["emitted"] == st["forwarded"]
    # the session-side ring (unbounded enough) still has every event: the
    # async queue bounds the *wrapped* backend, not the capture itself
    assert sess.n_events == total


def test_async_sink_flush_drains_and_emit_after_close_is_counted(tmp_path):
    path = str(tmp_path / "a.jsonl")
    a = AsyncSink(JsonlSink(path), maxsize=1024)
    sess = TraceSession("fl", sinks=[a])
    for i in range(50):
        sess.emit("dispatch", "d")
    assert a.flush(timeout_s=30)
    assert len(JsonlSink.load(path)) == 50      # all on disk pre-close
    a.close()
    sess.emit("dispatch", "late")               # dropped, but counted
    st = a.stats()
    assert st["offered"] == 51 and st["dropped"] == 1
    assert len(JsonlSink.load(path)) == 50


def test_async_sink_swallows_backend_errors():
    class Broken:
        def emit(self, e):
            raise IOError("disk full")

    a = AsyncSink(Broken(), maxsize=16)
    sess = TraceSession("broken", sinks=[a])
    for _ in range(5):
        sess.emit("dispatch", "d")
    a.close()
    st = a.stats()
    assert st["write_errors"] == 5
    assert st["forwarded"] == st["enqueued"]    # accounting still closes


# -- SamplingSink ------------------------------------------------------------

def test_sampling_sink_exact_per_kind_counts():
    ring = RingBufferSink()
    s = SamplingSink(ring, every={"dispatch": 10, "progress": 3})
    sess = TraceSession("samp", sinks=[s])
    for i in range(100):
        sess.emit("dispatch", f"d{i}")
    for i in range(10):
        sess.emit("progress", f"p{i}")
    sess.emit("transfer", "t")                  # default_every=1: kept
    st = s.stats()
    assert st["seen"] == {"dispatch": 100, "progress": 10, "transfer": 1}
    assert st["kept"] == {"dispatch": 10, "progress": 4, "transfer": 1}
    assert st["sampled_away"] == {"dispatch": 90, "progress": 6,
                                  "transfer": 0}
    assert st["total_sampled_away"] == 96
    # deterministic: the kept dispatches are every 10th starting at the 1st
    kept = [e.name for e in ring.events() if e.kind == "dispatch"]
    assert kept == [f"d{i}" for i in range(0, 100, 10)]


def test_sampling_sink_never_drops_barriers():
    ring = RingBufferSink()
    s = SamplingSink(ring, every={"progress": 1000})
    sess = TraceSession("sampb", sinks=[s])
    for i in range(5):
        sess.emit("progress", "noise")
    sess.barrier("sync")                        # 6th progress event
    names = [e.name for e in ring.events()]
    assert "obs.barrier" in names               # bypassed the 1-in-1000
    assert s.stats()["kept"]["progress"] == 2   # first noise + the barrier


# -- aggregation -------------------------------------------------------------

def _make_shards(tmp_path, n_shards=3, events_per=20):
    """Write n tagged shards with one shared barrier and known skews."""
    paths = []
    for p in range(n_shards):
        path = str(tmp_path / f"shard{p}.jsonl")
        with TraceSession(f"w{p}", jsonl_path=path,
                          tags={"host": "hostA", "process": p}) as s:
            s.barrier("start")
            for i in range(events_per):
                s.emit("dispatch", f"step{p}", dur_s=1e-4,
                       payload_bytes=8)
            s.emit("transfer", f"mv{p}", payload_bytes=100 * (p + 1))
        paths.append(path)
        time.sleep(0.002)       # skew the next session's t0
    return paths


def test_aggregate_summary_is_elementwise_sum_of_shards(tmp_path):
    """Acceptance: merged summary == elementwise sum of per-shard
    summaries (alignment metadata aside)."""
    paths = _make_shards(tmp_path)
    merged = aggregate(paths)
    ms = merged.summary()
    shard_sums = [summarize(sh.events) for sh in merged.shards]
    assert ms["events"] == sum(s["events"] for s in shard_sums)
    for kind in ("dispatch", "transfer", "progress"):
        assert ms["by_kind"].get(kind, 0) == \
            sum(s["by_kind"].get(kind, 0) for s in shard_sums)
        assert ms["payload_by_kind"].get(kind, 0) == \
            sum(s["payload_by_kind"].get(kind, 0) for s in shard_sums)
        assert ms["dur_s_by_kind"].get(kind, 0.0) == pytest.approx(
            sum(s["dur_s_by_kind"].get(kind, 0.0) for s in shard_sums))
    assert ms["total_payload_bytes"] == \
        sum(s["total_payload_bytes"] for s in shard_sums)
    assert ms["total_dispatch_s"] == pytest.approx(
        sum(s["total_dispatch_s"] for s in shard_sums))
    # per-shard by_name keys are disjoint here: merged carries them all
    for s in shard_sums:
        for name, row in s["by_name"].items():
            if name == "obs.barrier":
                continue
            assert ms["by_name"][name] == row


def test_aggregate_orders_by_aligned_clock_and_tags_provenance(tmp_path):
    paths = _make_shards(tmp_path, n_shards=2, events_per=5)
    merged = aggregate(paths)
    ts = [e.t for e in merged.events]
    assert ts == sorted(ts)                            # monotonic aligned t
    assert [e.seq for e in merged.events] == list(range(len(merged.events)))
    shards_seen = {e.meta["shard"] for e in merged.events}
    assert shards_seen == {"hostA/p0", "hostA/p1"}
    assert all("src_seq" in e.meta for e in merged.events)
    # barrier alignment engaged for the non-reference shard
    modes = {sh.shard_id: sh.align_mode for sh in merged.shards}
    assert modes["hostA/p0"] == "reference"
    assert modes["hostA/p1"] == "barrier"
    # the two barriers land (nearly) together on the aligned clock
    barriers = [e for e in merged.events if e.name == "obs.barrier"]
    assert len(barriers) == 2
    assert abs(barriers[0].t - barriers[1].t) < 1e-6


def test_aggregate_is_stable_under_remerge(tmp_path):
    paths = _make_shards(tmp_path, n_shards=2, events_per=8)
    merged = aggregate(paths)
    out = str(tmp_path / "merged.jsonl")
    merged.save(out)
    again = aggregate([out])
    assert [(e.seq, e.name, e.kind) for e in again.events] == \
        [(e.seq, e.name, e.kind) for e in merged.events]
    assert [e.t for e in again.events] == \
        pytest.approx([e.t for e in merged.events])


def test_aggregate_shuffled_shard_files_resorted_by_seq(tmp_path):
    paths = _make_shards(tmp_path, n_shards=2, events_per=10)
    # shuffle the lines of one shard file (async writers may reorder)
    with open(paths[1]) as f:
        lines = f.readlines()
    random.Random(0).shuffle(lines)
    with open(paths[1], "w") as f:
        f.writelines(lines)
    merged = aggregate(paths)
    ts = [e.t for e in merged.events]
    assert ts == sorted(ts)
    # within a shard, local seq order survives the shuffle
    p1 = [e.meta["src_seq"] for e in merged.events
          if e.meta["shard"] == "hostA/p1"]
    assert p1 == sorted(p1)


def test_aggregate_cli_writes_merged_jsonl(tmp_path, capsys):
    from repro.obs.aggregate import main
    paths = _make_shards(tmp_path, n_shards=2, events_per=3)
    out = str(tmp_path / "fleet.jsonl")
    rc = main(paths + ["-o", out, "--report", "4", "--summary"])
    assert rc == 0
    text = capsys.readouterr().out
    assert "AGGREGATED TIMELINE (2 shards" in text
    merged_events = JsonlSink.load(out)
    assert len(merged_events) == len(JsonlSink.load(paths[0])) + \
        len(JsonlSink.load(paths[1]))


# The hypothesis property test for alignment/merge lives in
# tests/test_obs_property.py — module-level importorskip would skip this
# whole file on hypothesis-less environments.


# -- LiveSummary / LiveServer ------------------------------------------------

def test_live_summary_matches_session_summary_schema():
    lv = LiveSummary("live")
    sess = TraceSession("live", sinks=[lv])
    empty = lv.snapshot()
    assert empty["events"] == 0
    assert set(empty["by_kind"]) == set(
        ("compile", "dispatch", "transfer", "graph_launch", "progress"))
    sess.emit("dispatch", "d", dur_s=0.25, payload_bytes=8)
    sess.emit("transfer", "mv", payload_bytes=100)
    snap, full = lv.snapshot(), sess.summary()
    for key in ("events", "by_kind", "dur_s_by_kind", "payload_by_kind",
                "by_name", "total_payload_bytes", "total_dispatch_s"):
        assert snap[key] == full[key], key


def test_live_server_poll_and_stream():
    import urllib.request

    lv = LiveSummary("srv")
    sess = TraceSession("srv", sinks=[lv])
    sess.emit("dispatch", "d")
    try:
        server = LiveServer(lv.snapshot).start()
    except OSError:
        pytest.skip("cannot bind localhost in this environment")
    try:
        url = server.url
        got = json.loads(urllib.request.urlopen(
            f"{url}/summary", timeout=10).read())
        assert got["events"] == 1 and got["by_kind"]["dispatch"] == 1
        ok = json.loads(urllib.request.urlopen(
            f"{url}/healthz", timeout=10).read())
        assert ok == {"ok": True}
        lines = urllib.request.urlopen(
            f"{url}/stream?interval=0.01&max=3", timeout=10).read()
        snaps = [json.loads(l) for l in lines.splitlines()]
        assert len(snaps) == 3 and all(s["events"] == 1 for s in snaps)
    finally:
        server.stop()


@pytest.mark.slow
def test_engine_live_summary_reflects_run():
    import numpy as np
    from repro.configs import SMOKE_ARCHS
    from repro.runtime.server import ContinuousBatchingServer, Request

    cfg = SMOKE_ARCHS["gemma-2b"]
    eng = ContinuousBatchingServer(cfg, batch_size=2, max_seq=32,
                                   tokens_per_launch=2)
    before = eng.live_summary()
    assert before["engine"]["active"] == 0
    rng = np.random.default_rng(0)
    for uid in range(3):
        eng.submit(Request(uid, rng.integers(
            0, cfg.vocab_size, size=4).astype(np.int32), max_new_tokens=4))
    eng.close_intake()
    eng.run()
    after = eng.live_summary()
    assert after["engine"]["tickets"]["done"] == 3
    assert after["engine"]["active"] == 0
    assert after["engine"]["tokens_emitted"] == 12
    assert after["by_kind"]["dispatch"] >= 1
    # the live snapshot agrees with the post-mortem session summary
    assert after["events"] == eng.session.summary()["events"]
