"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.dma import HybridMover
from repro.core.hlo import _link_bytes, dtype_bytes
from repro.optim import compress_int8, decompress_int8
from repro.runtime.fault_tolerance import plan_elastic_mesh
from repro.configs.base import pad_to_multiple

SET = dict(max_examples=50, deadline=None)


@given(st.integers(1, 1 << 30), st.integers(1, 4096))
@settings(**SET)
def test_pad_to_multiple_properties(x, m):
    p = pad_to_multiple(x, m)
    assert p % m == 0
    assert 0 <= p - x < m


@given(st.integers(0, 1 << 24), st.integers(0, 1 << 24), st.integers(1, 512))
@settings(**SET)
def test_link_bytes_nonnegative_and_bounded(res, opr, n):
    for op in ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute"):
        lb = _link_bytes(op, res, opr, n)
        assert lb >= 0
        assert lb <= 2 * max(res, opr)  # never more than 2x the buffer


@given(st.lists(st.floats(-1e3, 1e3, allow_nan=False, width=32),
                min_size=1, max_size=512))
@settings(**SET)
def test_int8_compression_bounded_error(xs):
    x = jnp.asarray(np.asarray(xs, np.float32))
    q, s = compress_int8(x)
    y = decompress_int8(q, s, x.shape, jnp.float32)
    # per-block max error <= scale/2 ~ max|block|/254 (+eps guard)
    err = np.max(np.abs(np.asarray(y) - np.asarray(x)))
    bound = max(1e-9, np.max(np.abs(np.asarray(x)))) / 127.0 + 1e-6
    assert err <= bound


@given(st.integers(1, 4096),
       st.lists(st.sampled_from([64, 128, 1408, 4096, 14336, 16384, 53248]),
                min_size=1, max_size=4))
@settings(**SET)
def test_elastic_mesh_always_valid(n_devices, dims):
    data, model = plan_elastic_mesh(n_devices, dims)
    assert data >= 1 and model >= 1
    assert data * model <= n_devices
    assert all(d % model == 0 for d in dims)


@given(st.integers(1, 1 << 20), st.integers(0, 1 << 22))
@settings(**SET)
def test_hybrid_mover_mode_is_threshold_function(threshold, nbytes):
    mover = HybridMover(threshold=threshold)
    x = np.zeros(max(1, nbytes), np.uint8)
    _, rec = mover.put(x)
    assert rec.mode == ("inline" if x.nbytes < threshold else "direct")


@given(st.integers(1, 1 << 20))
@settings(**SET)
def test_hybrid_mover_direct_at_exact_threshold(nbytes):
    """Boundary law: a payload of exactly threshold bytes goes direct."""
    _, rec = HybridMover(threshold=nbytes).put(np.zeros(nbytes, np.uint8))
    assert rec.mode == "direct"


@given(st.floats(0, 10, allow_nan=False), st.floats(0, 10, allow_nan=False),
       st.floats(0, 5, allow_nan=False), st.integers(0, 1000),
       st.integers(1, 1 << 16))
@settings(**SET)
def test_objective_monotone_in_dispatch_time(d1, d2, transfer_s, doorbells,
                                             tokens):
    """The tuner objective must strictly order by measured dispatch time
    when everything else is equal — otherwise search tunes the wrong way."""
    from repro.tune import Metrics, Objective
    obj = Objective()
    lo = Metrics(dispatch_s=min(d1, d2), transfer_s=transfer_s,
                 doorbells=doorbells, tokens=tokens)
    hi = Metrics(dispatch_s=max(d1, d2), transfer_s=transfer_s,
                 doorbells=doorbells, tokens=tokens)
    if d1 == d2:
        assert obj.score(lo) == obj.score(hi)
    else:
        assert obj.score(lo) < obj.score(hi)


@given(st.sampled_from(["f32", "bf16", "f16", "s8", "u32", "pred", "f64"]))
@settings(**SET)
def test_dtype_bytes_known(d):
    assert dtype_bytes(d) > 0
