"""Progress trackers (memory-semaphore protocol) + heartbeats."""
import time

import jax.numpy as jnp
import pytest

from repro.core import Heartbeat, ProgressTracker


def test_release_wait_elapsed():
    pt = ProgressTracker()
    a = pt.release(jnp.ones((8,)) * 3)
    b = pt.release(jnp.ones((8,)) * 4)
    dt = pt.elapsed(a, b)
    assert a.completed and b.completed
    assert dt >= 0
    assert a.payload != b.payload


def test_payload_ordering():
    pt = ProgressTracker()
    toks = [pt.release(jnp.zeros(2)) for _ in range(5)]
    assert [t.payload for t in toks] == [1, 2, 3, 4, 5]


def test_heartbeat_straggler_detection():
    hb = Heartbeat(3, factor=3.0)
    t = 0.0
    for i in range(10):  # workers 0,1 beat every 1s; worker 2 stops at t=3
        hb.beat(0, t)
        hb.beat(1, t)
        if t <= 3:
            hb.beat(2, t)
        t += 1.0
    assert hb.stragglers(now=t) == [2]
    assert hb.dead(timeout_s=5.0, now=t) == [2]
    assert hb.dead(timeout_s=100.0, now=t) == []
