"""repro.obs.trajectory: BENCH artifact diffing and the CI perf gate."""
import json

import pytest

from repro.obs.trajectory import (diff_metrics, direction, extract_metrics,
                                  load_artifact, main, trend_report)


def _artifact(pr, latency=10.0, tokens_per_s=500.0, dispatch_s=0.5,
              objective=7e-4, quick=True):
    return {
        "pr": pr, "quick": quick, "arch": "gemma-2b",
        "sections": {
            "dma": {"header": ["name", "nbytes", "latency_us",
                               "bandwidth_gib_s"],
                    "rows": [{"name": "inline", "nbytes": 256,
                              "latency_us": latency,
                              "bandwidth_gib_s": 1.0}]},
            "loadtest": {"header": ["mode", "requests", "tokens_per_s",
                                    "doorbells"],
                         "rows": [{"mode": "T4", "requests": 16,
                                   "tokens_per_s": tokens_per_s,
                                   "doorbells": 40}]},
        },
        "session_summary": {"events": 100, "total_dispatch_s": dispatch_s},
        "tuning": {"after": objective},
    }


def _write(tmp_path, name, art):
    p = str(tmp_path / name)
    with open(p, "w") as f:
        json.dump(art, f)
    return p


# -- direction inference -----------------------------------------------------

def test_direction_inference():
    assert direction("latency_us") == "lower"
    assert direction("ttft_p99_s") == "lower"
    assert direction("doorbells") == "lower"
    assert direction("doorbells_per_token") == "lower"
    assert direction("score_s_per_token") == "lower"
    assert direction("total_dispatch_s") == "lower"
    assert direction("tokens_per_s") == "higher"
    assert direction("tokens_per_doorbell") == "higher"
    assert direction("bandwidth_gib_s") == "higher"
    assert direction("steps_per_doorbell") == "higher"
    # identity / workload-size columns are never scored
    for col in ("name", "nbytes", "chain_len", "steps", "requests",
                "tokens", "command_bytes_or_bw"):
        assert direction(col) is None


def test_extract_metrics_keys_rows_by_identity_cells():
    m = extract_metrics(_artifact(7))
    assert m["dma/name=inline,nbytes=256/latency_us"] == (10.0, "lower")
    assert m["loadtest/mode=T4,requests=16/tokens_per_s"] == \
        (500.0, "higher")
    assert m["session/total_dispatch_s"] == (0.5, "lower")
    assert m["tuning/objective_after"] == (7e-4, "lower")
    # identity columns did not become metrics
    assert not any(k.endswith("/nbytes") for k in m)


def test_diff_metrics_direction_aware():
    base = extract_metrics(_artifact(7))
    # latency doubled (bad), throughput doubled (good)
    cand = extract_metrics(_artifact(8, latency=20.0, tokens_per_s=1000.0))
    regs, imps, n = diff_metrics(base, cand, threshold=0.25)
    assert [r.metric for r in regs] == \
        ["dma/name=inline,nbytes=256/latency_us"]
    assert regs[0].worsened == pytest.approx(1.0)
    assert [r.metric for r in imps] == \
        ["loadtest/mode=T4,requests=16/tokens_per_s"]
    # throughput *drop* is a regression for a higher-is-better metric
    regs2, _, _ = diff_metrics(base,
                               extract_metrics(_artifact(8,
                                                         tokens_per_s=100.0)),
                               threshold=0.25)
    assert any("tokens_per_s" in r.metric for r in regs2)


# -- CLI gate (acceptance: nonzero exit on injected synthetic regression) ----

def test_cli_exits_nonzero_on_injected_regression(tmp_path, capsys):
    b = _write(tmp_path, "BENCH_7.json", _artifact(7))
    c = _write(tmp_path, "BENCH_8.json",
               _artifact(8, latency=30.0))          # 3x latency regression
    rc = main([b, c])
    assert rc == 1
    assert "REGRESSION" in capsys.readouterr().out


def test_cli_warn_only_reports_but_exits_zero(tmp_path, capsys):
    b = _write(tmp_path, "BENCH_7.json", _artifact(7))
    c = _write(tmp_path, "BENCH_8.json", _artifact(8, latency=30.0))
    rc = main(["--baseline", b, "--candidate", c, "--warn-only"])
    assert rc == 0
    assert "REGRESSION" in capsys.readouterr().out


def test_cli_clean_run_exits_zero_and_writes_report(tmp_path, capsys):
    b = _write(tmp_path, "BENCH_7.json", _artifact(7))
    c = _write(tmp_path, "BENCH_8.json",
               _artifact(8, latency=9.5, tokens_per_s=520.0))
    report = str(tmp_path / "TREND.md")
    rc = main([b, c, "--report", report])
    assert rc == 0
    md = open(report).read()
    assert "# BENCH trajectory report" in md
    assert "pr 7 → pr 8" in md


def test_cli_orders_positional_artifacts_by_pr_number(tmp_path):
    # regression is 6→7; 7→8 (the gate pair) is clean even though the
    # files are passed out of order
    a6 = _write(tmp_path, "BENCH_6.json", _artifact(6, latency=10.0))
    a7 = _write(tmp_path, "BENCH_7.json", _artifact(7, latency=30.0))
    a8 = _write(tmp_path, "BENCH_8.json", _artifact(8, latency=31.0))
    assert main([a8, a6, a7]) == 0
    # flip it: make the final pair regress
    a9 = _write(tmp_path, "BENCH_9.json", _artifact(9, latency=90.0))
    assert main([a9, a6, a8, a7]) == 1


def test_trend_report_flags_quick_full_mismatch(tmp_path):
    base = _artifact(7, quick=False)
    base["_path"] = "BENCH_7.json"
    cand = _artifact(8, quick=True, latency=30.0)
    cand["_path"] = "BENCH_ci.json"
    md, regs = trend_report([base, cand], threshold=0.25)
    assert "quick/full scale mismatch" in md
    assert regs                                     # still computed


def test_cli_unreadable_artifact_exits_two(tmp_path):
    bad = str(tmp_path / "BENCH_bad.json")
    with open(bad, "w") as f:
        f.write("{not json")
    ok = _write(tmp_path, "BENCH_7.json", _artifact(7))
    assert main([ok, bad]) == 2
    not_bench = _write(tmp_path, "BENCH_9.json", {"rows": []})
    assert main([ok, not_bench]) == 2


def test_zero_baseline_metrics_are_skipped(tmp_path):
    b = _write(tmp_path, "BENCH_7.json", _artifact(7, dispatch_s=0.0))
    c = _write(tmp_path, "BENCH_8.json", _artifact(8, dispatch_s=5.0))
    # only the zero-baseline metric changed -> no regression flagged
    assert main([b, c]) == 0


def test_load_artifact_rejects_non_bench_json(tmp_path):
    p = str(tmp_path / "x.json")
    with open(p, "w") as f:
        json.dump({"hello": 1}, f)
    with pytest.raises(ValueError):
        load_artifact(p)
