"""Submission-policy autotuner: objective, search, policy, auto-apply."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.configs import SMOKE_ARCHS
from repro.core import TraceSession
from repro.core.dma import INLINE_THRESHOLD_DEFAULT, HybridMover
from repro.tune import (Knob, Metrics, Objective, ObjectiveWeights, Policy,
                        activate_policy, clear_active_policy,
                        coordinate_descent, load_policy, load_policy_for,
                        metrics_from_summary, parse_spec, parse_value,
                        save_policy)
from repro.tune.autotune import CandidateEvaluator, WorkloadSpec, default_knobs

CFG = SMOKE_ARCHS["deepseek-7b"]


# -- spec parsing (the hillclimb rsplit fix) -------------------------------

def test_parse_value_types():
    assert parse_value("True") is True
    assert parse_value("False") is False
    assert parse_value("7") == 7
    assert parse_value("0.5") == 0.5
    assert parse_value("abc") == "abc"


def test_parse_spec_plain():
    assert parse_spec("tag:123") == ("tag", 123)


def test_parse_spec_key_with_colons():
    """Regression: tags are op paths that may contain ':' — only the LAST
    colon separates the value (split(':') used to shear the key apart)."""
    key, val = parse_spec("jit(step)/fusion:attn:softmax:4.2e6")
    assert key == "jit(step)/fusion:attn:softmax"
    assert val == pytest.approx(4.2e6)


def test_parse_spec_rejects_no_colon():
    with pytest.raises(ValueError):
        parse_spec("novalue")


# -- search ----------------------------------------------------------------

def test_coordinate_descent_finds_separable_minimum():
    knobs = [Knob("a", (1, 2, 3, 4), default=1),
             Knob("b", (0, 5, 10), default=0)]
    res = coordinate_descent(lambda k: (k["a"] - 3) ** 2 + abs(k["b"] - 5),
                             knobs)
    assert res.best == {"a": 3, "b": 5}
    assert res.best_score == 0.0
    assert res.start_score > res.best_score
    assert 0 < res.improvement <= 1


def test_coordinate_descent_caches_evaluations():
    calls = []

    def ev(k):
        calls.append(dict(k))
        return k["a"]

    res = coordinate_descent(ev, [Knob("a", (3, 2, 1))], max_rounds=4)
    assert res.best == {"a": 1}
    # each distinct assignment evaluated exactly once despite multiple rounds
    assert len(calls) == len(res.trials) == 3


def test_knob_requires_values():
    with pytest.raises(ValueError):
        Knob("empty", ())


# -- objective -------------------------------------------------------------

def test_metrics_from_summary_reads_session_accumulators():
    with TraceSession(name="t") as sess:
        sess.emit("dispatch", "d", dur_s=0.25)
        sess.emit("dispatch", "d", dur_s=0.25)
        sess.emit("transfer", "inline_put", dur_s=0.5, payload_bytes=2**30)
        sess.emit("compile", "c", dur_s=1.0)
        m = metrics_from_summary(sess.summary(), tokens=10)
    assert m.dispatch_s == pytest.approx(0.5)
    assert m.doorbells == 2
    assert m.transfer_s == pytest.approx(0.5)
    assert m.transfer_bytes == 2**30
    assert m.compile_s == pytest.approx(1.0)
    assert m.doorbells_per_token == pytest.approx(0.2)
    assert m.transfer_bandwidth_gib_s == pytest.approx(2.0)


def test_metrics_delta_excludes_warmup():
    with TraceSession(name="t") as sess:
        sess.emit("dispatch", "warm", dur_s=5.0)
        before = sess.summary()
        sess.emit("dispatch", "steady", dur_s=0.1)
        m = metrics_from_summary(sess.summary(), before, tokens=1)
    assert m.dispatch_s == pytest.approx(0.1)
    assert m.doorbells == 1


def test_objective_scores_per_token_and_compile_free():
    obj = Objective(ObjectiveWeights(dispatch=1.0, transfer=1.0,
                                     doorbell_cost_s=0.0))
    m = Metrics(dispatch_s=1.0, transfer_s=0.5, compile_s=100.0, tokens=10)
    assert obj.score(m) == pytest.approx(0.15)  # compile not charged


def test_objective_weights_validated():
    with pytest.raises(ValueError):
        ObjectiveWeights(dispatch=0.0)
    with pytest.raises(ValueError):
        ObjectiveWeights(doorbell_cost_s=-1.0)


# -- policy persistence + auto-apply ---------------------------------------

def _mk_policy(**knobs):
    return Policy(arch=CFG.name, platform="cpu", device_count=1,
                  knobs=knobs, objective={"before": 2.0, "after": 1.0})


def test_policy_roundtrip(tmp_path):
    pol = _mk_policy(tokens_per_launch=4, dma_threshold_bytes=0)
    path = save_policy(pol, str(tmp_path))
    assert os.path.basename(path) == f"{CFG.name}__cpu__d1.json"
    with open(path) as f:
        assert json.load(f)["knobs"]["tokens_per_launch"] == 4
    loaded = load_policy(CFG.name, "cpu", 1, str(tmp_path))
    assert loaded == pol


def test_load_policy_relaxed_device_count(tmp_path):
    save_policy(_mk_policy(tokens_per_launch=2), str(tmp_path))
    # same arch+platform, different device count -> still found
    assert load_policy(CFG.name, "cpu", 8, str(tmp_path)).knobs[
        "tokens_per_launch"] == 2
    assert load_policy("other-arch", "cpu", 1, str(tmp_path)) is None


def test_load_policy_disabled_by_env(tmp_path, monkeypatch):
    save_policy(_mk_policy(tokens_per_launch=2), str(tmp_path))
    monkeypatch.setenv("REPRO_POLICY_DISABLE", "1")
    assert load_policy(CFG.name, "cpu", 1, str(tmp_path)) is None


def test_server_auto_applies_persisted_policy():
    # conftest points REPRO_POLICY_DIR at an empty per-test dir
    from repro.runtime.server import Server
    save_policy(_mk_policy(tokens_per_launch=3))
    srv = Server(CFG, batch_size=2, max_seq=64)      # knob left unset
    assert srv.T == 3
    assert srv.policy is not None
    explicit = Server(CFG, batch_size=2, max_seq=64, tokens_per_launch=1)
    assert explicit.T == 1 and explicit.policy is None


def test_trainer_auto_applies_persisted_policy():
    from repro.configs.shapes import ShapeConfig
    from repro.runtime.trainer import Trainer
    save_policy(_mk_policy(steps_per_launch=2))
    tr = Trainer(CFG, ShapeConfig("tiny", 32, 2, "train"))
    assert tr.k == 2
    out = tr.train(4)
    assert out["steps"] == 4 and out["doorbells"] == 2


def test_hybrid_mover_reads_active_policy_threshold():
    pol = _mk_policy(dma_threshold_bytes=0)
    activate_policy(pol)
    try:
        mover = HybridMover()                        # threshold unset
        assert mover.threshold == 0
        _, rec = mover.put(np.zeros(4, np.uint8))
        assert rec.mode == "direct"
    finally:
        clear_active_policy()
    assert HybridMover().threshold == INLINE_THRESHOLD_DEFAULT


# -- end-to-end tune -------------------------------------------------------

def test_default_knobs_cover_requested_workloads():
    knobs = default_knobs(("dma", "serve", "train"))
    assert [k.name for k in knobs] == [
        "dma_threshold_bytes", "tokens_per_launch", "steps_per_launch"]
    with pytest.raises(KeyError):
        default_knobs(("nope",))


def test_evaluator_rejects_unknown_workload():
    with pytest.raises(ValueError):
        CandidateEvaluator(CFG, workloads=("dma", "nope"))


def test_evaluator_caches_per_workload_subkey():
    ev = CandidateEvaluator(CFG, spec=WorkloadSpec(dma_repeats=1,
                                                   dma_sizes=(64, 4096)),
                            workloads=("dma",))
    s1, info = ev({"dma_threshold_bytes": 1024})
    assert "dma" in info and info["dma"]["tokens"] == 2
    n_cached = len(ev._cache)
    s2, _ = ev({"dma_threshold_bytes": 1024})
    assert s2 == s1 and len(ev._cache) == n_cached


@pytest.mark.slow
def test_tune_cli_persists_policy_and_server_applies_it(tmp_path):
    """The acceptance loop: tune -> policy JSON -> fresh process Server run
    picks the knobs up, with before/after objective in the policy."""
    env = dict(os.environ)
    env["REPRO_POLICY_DIR"] = str(tmp_path)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(__file__), "..", "src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.tune", "--arch", "gemma-2b",
         "--workloads", "dma,serve", "--rounds", "1", "--new-tokens", "4",
         "--max-seq", "32", "--no-verify"],
        capture_output=True, text=True, timeout=900, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    # keyed by cfg.name (what Server looks up), not the registry alias
    name = SMOKE_ARCHS["gemma-2b"].name
    path = os.path.join(str(tmp_path), f"{name}__cpu__d1.json")
    assert os.path.exists(path)
    with open(path) as f:
        pol = json.load(f)
    assert set(pol["knobs"]) == {"dma_threshold_bytes", "tokens_per_launch"}
    assert pol["objective"]["after"] <= pol["objective"]["before"]
    assert pol["objective"]["trials"]
    # a separate process's Server auto-applies the persisted knobs
    check = subprocess.run(
        [sys.executable, "-c", textwrap.dedent("""
            from repro.configs import SMOKE_ARCHS
            from repro.runtime.server import Server
            srv = Server(SMOKE_ARCHS["gemma-2b"], batch_size=2, max_seq=32)
            assert srv.policy is not None
            print("applied", srv.T)
        """)],
        capture_output=True, text=True, timeout=300, env=env)
    assert check.returncode == 0, check.stderr[-3000:]
    assert check.stdout.startswith("applied "), check.stdout
    assert int(check.stdout.split()[1]) == pol["knobs"]["tokens_per_launch"]
