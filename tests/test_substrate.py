"""Substrate: optimizer, compression, data pipeline, checkpoint, FT."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKE_ARCHS
from repro.configs.shapes import ShapeConfig
from repro.data.pipeline import SyntheticTokens, make_pipeline
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         compress_int8, decompress_int8, cosine_schedule,
                         ef_init, ef_compress_update)
from repro.runtime.checkpoint import CheckpointManager, latest_step, restore, save
from repro.runtime.fault_tolerance import (FaultPolicy, FleetMonitor,
                                           plan_elastic_mesh)

KEY = jax.random.PRNGKey(0)


# ------------------------------------------------------------------ optim
def test_adamw_reduces_quadratic_loss():
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2)

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, opt, _ = adamw_update(g, opt, params, lr=0.05,
                                      weight_decay=0.0)
    assert float(loss(params)) < 1e-2


def test_grad_clip():
    g = {"a": jnp.ones((4,)) * 100.0}
    clipped, gn = clip_by_global_norm(g, 1.0)
    assert float(gn) == pytest.approx(200.0)
    norm = float(jnp.sqrt(jnp.sum(clipped["a"] ** 2)))
    assert norm == pytest.approx(1.0, rel=1e-3)


def test_cosine_schedule_monotone_regions():
    w, total, peak = 10, 100, 1.0
    lrs = [float(cosine_schedule(s, w, total, peak)) for s in range(100)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(peak, rel=1e-3)
    assert lrs[-1] < lrs[15]


# ------------------------------------------------------------------ compression
def test_int8_roundtrip_error_small():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(1000,)) * 0.01,
                    jnp.float32)
    q, s = compress_int8(x)
    y = decompress_int8(q, s, x.shape, jnp.float32)
    rel = float(jnp.linalg.norm(y - x) / jnp.linalg.norm(x))
    assert rel < 0.01
    assert q.dtype == jnp.int8


def test_error_feedback_preserves_signal():
    """With EF, the accumulated compressed sum tracks the true sum."""
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(512,)) * 1e-4,
                          jnp.float32)}
    ef = ef_init(g)
    acc = jnp.zeros((512,))
    for _ in range(20):
        cg, ef = ef_compress_update(g, ef)
        acc = acc + cg["w"]
    true = 20 * g["w"]
    rel = float(jnp.linalg.norm(acc - true) / jnp.linalg.norm(true))
    assert rel < 0.05


# ------------------------------------------------------------------ data
def test_pipeline_deterministic_and_host_sharded():
    cfg = SMOKE_ARCHS["deepseek-7b"]
    shape = ShapeConfig("t", 32, 8, "train")
    a = SyntheticTokens(cfg, shape, seed=3).batch_at(17)
    b = SyntheticTokens(cfg, shape, seed=3).batch_at(17)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticTokens(cfg, shape, seed=3).batch_at(18)
    assert not np.array_equal(a["tokens"], c["tokens"])
    h0 = SyntheticTokens(cfg, shape, seed=3, host_id=0, n_hosts=2)
    h1 = SyntheticTokens(cfg, shape, seed=3, host_id=1, n_hosts=2)
    assert h0.host_batch == 4
    assert not np.array_equal(h0.batch_at(0)["tokens"],
                              h1.batch_at(0)["tokens"])
    assert a["tokens"].max() < cfg.vocab_size


def test_prefetcher_orders_steps():
    cfg = SMOKE_ARCHS["deepseek-7b"]
    pipe = make_pipeline(cfg, ShapeConfig("t", 32, 4, "train"),
                         start_step=5)
    s0, _ = pipe.next()
    s1, _ = pipe.next()
    pipe.stop()
    assert (s0, s1) == (5, 6)


# ------------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip_bf16():
    tree = {"a": jnp.asarray([[1.5, -2.25]], jnp.bfloat16),
            "b": {"c": jnp.arange(6, dtype=jnp.int32)}}
    with tempfile.TemporaryDirectory() as d:
        save(d, 7, tree, extra={"next_step": 8})
        out, step, extra = restore(d, tree)
        assert step == 7 and extra["next_step"] == 8
        np.testing.assert_array_equal(np.asarray(out["a"], np.float32),
                                      np.asarray(tree["a"], np.float32))
        assert out["a"].dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(out["b"]["c"]),
                                      np.asarray(tree["b"]["c"]))


def test_checkpoint_manager_retention():
    tree = {"w": jnp.zeros((4,))}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep_last_n=2, every_steps=1)
        for s in (1, 2, 3, 4):
            mgr.maybe_save(s, tree)
        mgr.wait()
        mgr._gc()
        steps = sorted(int(x.split("_")[1]) for x in os.listdir(d)
                       if x.startswith("step_"))
        assert steps == [3, 4]
        assert latest_step(d) == 4


def test_checkpoint_atomicity_no_tmp_left():
    tree = {"w": jnp.zeros((4,))}
    with tempfile.TemporaryDirectory() as d:
        save(d, 1, tree)
        assert not any(x.startswith("tmp.") for x in os.listdir(d))


# ------------------------------------------------------------------ fault tolerance
def test_fleet_monitor_detects_dead_and_restarts():
    mon = FleetMonitor(4, FaultPolicy(dead_timeout_s=5.0))
    t = 0.0
    for i in range(8):
        for w in range(4):
            if w == 2 and t > 3:
                continue  # worker 2 dies at t=3
            mon.step_completed(w, t)
        t += 1.0
    stragglers, dead = mon.check(now=t + 5)
    assert 2 in dead
    assert mon.should_restart(dead)


def test_plan_elastic_mesh_divisibility():
    # llama3-like dims: after losing chips, model axis must still divide
    dims = [53248, 128, 16384]
    assert plan_elastic_mesh(256, dims) == (16, 16)
    data, model = plan_elastic_mesh(240, dims)  # lost a host (16 chips)
    assert data * model <= 240
    assert all(d % model == 0 for d in dims)
    assert plan_elastic_mesh(7, [5, 3]) == (7, 1)  # degenerate fallback
