"""Sharding rules: spec resolution, divisibility fallback, ZeRO-1 (host-only,
no devices needed — specs are pure functions of paths/shapes)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, resolve
from repro.distributed import sharding as sh


class FakeMesh:
    """Duck-typed mesh: axis_names + shape dict (no devices touched)."""

    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)

    @property
    def size(self):
        n = 1
        for v in self.shape.values():
            n *= v
        return n


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _rules(arch, mesh=MESH, **kw):
    cfg = resolve(ARCHS[arch], model_axis=mesh.shape["model"])
    return sh.ShardingRules(mesh, cfg, **kw), cfg


def test_attention_tp_specs():
    rules, cfg = _rules("deepseek-7b")
    assert rules.param_spec("layers/attn/wq", (30, 4096, 32, 128)) == \
        P(None, None, "model", None)
    assert rules.param_spec("layers/attn/wo", (30, 32, 128, 4096)) == \
        P(None, "model", None, None)
    assert rules.param_spec("layers/mlp/w_gate", (30, 4096, 11008)) == \
        P(None, None, "model")
    assert rules.param_spec("emb/embed", (102400, 4096)) == P("model", None)


def test_kv_heads_replicated_when_indivisible():
    rules, cfg = _rules("llama3-405b")  # kv=8 on 16-way model axis
    spec = rules.param_spec("layers/attn/wk", (126, 16384, 8, 128))
    assert spec == P(None, ("pod", "data") if False else ("data",), None, None) \
        or spec[2] is None  # kv-head dim must NOT be model-sharded
    assert len(rules.dropped) >= 1


def test_fsdp_adds_dp_axis():
    rules, cfg = _rules("llama3-405b")
    assert cfg.fsdp
    spec = rules.param_spec("layers/mlp/w_gate", (126, 16384, 53248))
    assert spec == P(None, ("data",), "model")
    rules_mp, _ = _rules("llama3-405b", mesh=MESH_MP)
    spec = rules_mp.param_spec("layers/mlp/w_gate", (126, 16384, 53248))
    assert spec == P(None, ("pod", "data"), "model")


def test_zero1_opt_state_sharded_over_dp():
    rules, cfg = _rules("deepseek-7b")  # fsdp off -> ZeRO-1 adds dp
    spec = rules.opt_spec("m/layers/mlp/w_gate", (30, 4096, 11008))
    flat = [a for ax in spec for a in (ax if isinstance(ax, tuple) else (ax,))]
    assert "data" in flat and "model" in flat


def test_norms_replicated():
    rules, _ = _rules("qwen3-8b")
    assert rules.param_spec("layers/ln1/scale", (36, 4096)) == P()


def test_vocab_and_head_padding():
    cfg = resolve(ARCHS["whisper-medium"], 16)
    assert cfg.vocab_padded % 16 == 0 and cfg.vocab_padded >= 51865
    cfg = resolve(ARCHS["llava-next-34b"], 16)
    assert cfg.n_heads_padded == 64
    cfg = resolve(ARCHS["mamba2-780m"], 16)
    assert cfg.vocab_padded % 16 == 0
    # already-divisible archs stay exact
    cfg = resolve(ARCHS["gemma-2b"], 16)
    assert cfg.vocab_padded == 256000


def test_batch_spec_fallback_batch1():
    rules, _ = _rules("jamba-v0.1-52b")
    assert rules.batch_spec(256) == "data"
    assert rules.batch_spec(1) is None  # long_500k: replicate batch


def test_kv_cache_seq_sharding_when_heads_indivisible():
    rules, _ = _rules("llama3-405b")
    # [L, B, S, Hkv, hd] with kv=8 (indivisible): sequence gets 'model'
    spec = rules.state_spec("k", (126, 128, 32768, 8, 128))
    assert spec[2] == "model" and spec[3] is None
    assert spec[1] == "data"
    # batch=1 long-context: seq picks up data too
    spec1 = rules.state_spec("k", (4, 1, 1, 524288, 8, 128))
    assert spec1[3] == ("data", "model")


def test_moe_expert_internal_tp():
    rules, _ = _rules("grok-1-314b")
    spec = rules.param_spec("layers/moe/w_gate", (64, 8, 6144, 32768))
    assert spec[-1] == "model"
    assert spec[1] is None  # experts replicated in baseline
