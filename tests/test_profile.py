"""Span attribution profiler: histograms, SpanProfile, export, store.

Covers the causal-attribution pipeline end to end: LogHistogram percentile
accuracy bounds, per-span-path command attribution (stamped roll-up +
declared shares), survival of span identity through a JSONL round-trip and
a cross-shard :func:`repro.obs.aggregate` merge, Chrome-trace/Perfetto
export schema sanity, the persistent metrics store, and the trajectory
gate's deterministic-count enforcement.
"""
import json
import math
import os
import threading

import pytest

from repro.core import JsonlSink, TraceSession
from repro.obs import LogHistogram, MetricsStore, SpanProfile, aggregate
from repro.obs.export import export, to_chrome_trace
from repro.obs.trajectory import is_count_metric


# -- LogHistogram ------------------------------------------------------------

def _exact_percentile(xs, p):
    """Nearest-rank percentile on raw samples (the reference)."""
    vals = sorted(xs)
    rank = max(1, min(len(vals), math.ceil(p / 100.0 * len(vals))))
    return vals[rank - 1]


def test_log_histogram_percentile_error_bound():
    """p50/p90/p99 within the documented sqrt(growth)-1 relative error of
    the exact nearest-rank percentile, across 3 decades of dynamic range."""
    import random
    rng = random.Random(7)
    growth = 1.15
    bound = math.sqrt(growth) - 1.0 + 1e-9
    h = LogHistogram(growth)
    xs = [math.exp(rng.uniform(math.log(1e-4), math.log(1e-1)))
          for _ in range(5000)]
    for x in xs:
        h.add(x)
    assert h.n == 5000
    for p in (50.0, 90.0, 99.0):
        exact = _exact_percentile(xs, p)
        got = h.percentile(p)
        assert abs(got - exact) / exact <= bound, (p, got, exact)
    assert h.min == min(xs) and h.max == max(xs)
    assert h.mean == pytest.approx(sum(xs) / len(xs))


def test_log_histogram_zero_and_negative_bucket():
    h = LogHistogram()
    for v in (0.0, -1.0, 0.0):
        h.add(v)
    assert h.percentile(50.0) <= 0.0          # clamped into observed range
    h.add(5.0)
    assert h.percentile(99.0) == pytest.approx(5.0, rel=math.sqrt(1.15) - 1)
    # all-zero percentile is 0, not a stale +inf min
    h2 = LogHistogram()
    h2.add(0.0)
    assert h2.percentile(50.0) == 0.0


def test_log_histogram_merge_equals_combined_feed():
    a, b, both = LogHistogram(), LogHistogram(), LogHistogram()
    for i in range(1, 200):
        v = 0.001 * i
        (a if i % 2 else b).add(v)
        both.add(v)
    a.merge(b)
    for p in (50.0, 90.0, 99.0):
        assert a.percentile(p) == both.percentile(p)
    assert a.n == both.n and a.total == pytest.approx(both.total)
    other = LogHistogram(2.0)
    other.add(1.0)                            # empty merges are always OK
    with pytest.raises(ValueError, match="growth"):
        a.merge(other)


def test_log_histogram_dict_round_trip():
    h = LogHistogram()
    for v in (0.0, 1e-4, 3.7, 3.7, 120.0):
        h.add(v)
    h2 = LogHistogram.from_dict(json.loads(json.dumps(h.to_dict())))
    assert h2.n == h.n and h2.min == h.min and h2.max == h.max
    for p in (50.0, 99.0):
        assert h2.percentile(p) == h.percentile(p)


# -- span stamping + SpanProfile --------------------------------------------

def _spanned_session(**session_kw):
    """request > decode_iter nesting with stamped + declared attribution."""
    sess = TraceSession("prof", **session_kw)
    with sess.span("request", uid=1):
        sess.emit("dispatch", "prefill", dur_s=1e-3, payload_bytes=100)
        with sess.span("decode_iter"):
            sess.emit("dispatch", "decode", dur_s=2e-3, payload_bytes=40)
            sess.emit("graph_launch", "g", dur_s=1e-4)
    h = sess.start_span("bg_request")         # manual, overlapping span
    sess.emit("transfer", "weights", payload_bytes=999)  # NOT under bg span
    h.end(doorbells=3, payload=12)            # declared share
    return sess


def test_span_profile_rollup_and_declared_attribution():
    sess = _spanned_session()
    prof = SpanProfile.from_events(sess.timeline())
    spans = prof.snapshot()["spans"]
    assert set(spans) == {"request", "request/decode_iter", "bg_request"}
    # roll-up: the parent path sees nested dispatches + graph launch
    req = spans["request"]
    assert req["doorbells"] == 2              # prefill + decode
    assert req["graph_launches"] == 1
    assert req["payload_bytes"] == 140
    inner = spans["request/decode_iter"]
    assert inner["doorbells"] == 1 and inner["payload_bytes"] == 40
    # declared-only manual span: nothing stamped, everything declared
    bg = spans["bg_request"]
    assert bg["doorbells"] == 3 and bg["payload_bytes"] == 12
    assert bg["events"] == 0


def test_span_profile_sink_equals_post_mortem():
    live = SpanProfile()
    sess = TraceSession("prof", sinks=[live])
    with sess.span("step"):
        sess.emit("dispatch", "d", dur_s=1e-3)
    post = SpanProfile.from_events(sess.timeline())
    assert live.snapshot()["spans"] == post.snapshot()["spans"]


def test_span_profile_store_metrics_flat_ids():
    sess = _spanned_session()
    flat = SpanProfile.from_events(sess.timeline()).store_metrics()
    assert flat["request/doorbells"] == 2.0
    assert flat["request/decode_iter/payload_bytes"] == 40.0
    assert "request/wall_s_p50" in flat
    assert all(isinstance(v, float) for v in flat.values())


def test_span_attribution_survives_jsonl_round_trip(tmp_path):
    path = os.path.join(tmp_path, "trace.jsonl")
    sess = _spanned_session(jsonl_path=path)
    sess.close()
    direct = SpanProfile.from_events(sess.timeline()).snapshot()["spans"]
    loaded = SpanProfile.from_events(JsonlSink.load(path)).snapshot()["spans"]
    assert loaded == direct


def test_span_attribution_survives_aggregate_merge(tmp_path):
    """Two shards reuse the same local span ids; the merged profile must
    keep them apart (span identity is deduplicated per shard)."""
    paths = []
    for p in range(2):
        path = os.path.join(tmp_path, f"trace.p{p}.jsonl")
        sess = TraceSession("fleet", jsonl_path=path,
                            tags={"host": "h", "process": p})
        sess.barrier("sync")
        with sess.span("request", uid=p):      # same local span_id on both
            sess.emit("dispatch", "d", dur_s=1e-3, payload_bytes=10 + p)
        sess.close()
        paths.append(path)
    merged = aggregate(paths)
    spans = SpanProfile.from_events(merged.events).snapshot()["spans"]
    req = spans["request"]
    assert req["spans"] == 2                   # one per shard, not merged
    assert req["doorbells"] == 2
    assert req["payload_bytes"] == 21


def test_span_profile_report_renders():
    txt = SpanProfile.from_events(_spanned_session().timeline()).report()
    assert "SPAN PROFILE" in txt and "request/decode_iter" in txt


# -- contextvar span semantics ----------------------------------------------

def test_span_nesting_stamps_path_and_ancestor_chain():
    sess = TraceSession("nest")
    with sess.span("a") as ha:
        with sess.span("b") as hb:
            e = sess.emit("dispatch", "d")
    assert e.meta["span_path"] == "a/b"
    assert e.meta["span_ids"] == [ha.span_id, hb.span_id]
    assert e.meta["parent_span_id"] == ha.span_id
    # span-end events carry their own identity and duration
    ends = [x for x in sess.timeline() if x.name == "obs.span"]
    assert [x.meta["span"] for x in ends] == ["b", "a"]
    assert all(x.dur_s >= 0.0 for x in ends)


def test_span_contextvar_isolated_across_threads():
    """A thread's emits are stamped only with spans that thread opened."""
    sess = TraceSession("threads")
    seen = {}
    go = threading.Barrier(2)

    def worker(name):
        go.wait()
        with sess.span(name):
            e = sess.emit("dispatch", f"d_{name}")
        seen[name] = e.meta

    ts = [threading.Thread(target=worker, args=(n,)) for n in ("t1", "t2")]
    with sess.span("main_only"):
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        e_main = sess.emit("dispatch", "d_main")
    assert e_main.meta["span_path"] == "main_only"
    assert seen["t1"]["span_path"] == "t1"    # no main_only contamination
    assert seen["t2"]["span_path"] == "t2"
    assert seen["t1"]["span_id"] != seen["t2"]["span_id"]


# -- Chrome-trace / Perfetto export -----------------------------------------

def test_chrome_trace_schema_and_nesting():
    sess = _spanned_session()
    trace = to_chrome_trace(sess.timeline(), trace_name="t")
    evs = trace["traceEvents"]
    assert trace["displayTimeUnit"] == "ms"
    assert trace["otherData"]["trace"] == "t"
    phases = {e["ph"] for e in evs}
    assert {"M", "X", "b", "e"} <= phases
    for e in evs:                              # minimal per-event schema
        assert {"ph", "pid", "tid"} <= set(e)
        if e["ph"] in ("X", "b", "e", "i"):
            assert "ts" in e and "name" in e
        if e["ph"] == "X":
            assert e["dur"] >= 0.0
    # scoped spans are complete events that nest in time on one track
    spans_x = [e for e in evs if e["ph"] == "X" and e.get("cat") == "span"]
    by_name = {e["name"]: e for e in spans_x}
    outer, inner = by_name["request"], by_name["decode_iter"]
    assert outer["tid"] == inner["tid"]
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1e-6
    # the manual overlapping span exports as an async begin/end pair
    asyncs = [e for e in evs if e["ph"] in ("b", "e")]
    assert {e["name"] for e in asyncs} == {"bg_request"}
    b_ev = next(e for e in asyncs if e["ph"] == "b")
    e_ev = next(e for e in asyncs if e["ph"] == "e")
    assert b_ev["id"] == e_ev["id"] and b_ev["ts"] <= e_ev["ts"]
    # non-span events ride per-kind named tracks
    names = {e["args"]["name"] for e in evs if e["ph"] == "M"
             and e["name"] == "thread_name"}
    assert "dispatch" in names


def test_chrome_trace_shards_become_processes(tmp_path):
    paths = []
    for p in range(2):
        path = os.path.join(tmp_path, f"s.p{p}.jsonl")
        sess = TraceSession("fleet", jsonl_path=path,
                            tags={"host": "h", "process": p})
        sess.barrier("sync")
        with sess.span("request"):
            sess.emit("dispatch", "d")
        sess.close()
        paths.append(path)
    out = os.path.join(tmp_path, "perfetto.json")
    trace = export(paths, out)
    assert len(trace["otherData"]["shards"]) == 2
    assert {e["pid"] for e in trace["traceEvents"]} == {0, 1}
    with open(out) as f:                       # written file parses
        assert json.load(f)["traceEvents"]
    # timestamps never negative (Perfetto requirement after alignment)
    assert all(e["ts"] >= 0.0 for e in trace["traceEvents"] if "ts" in e)


def test_export_cli_single_shard(tmp_path, capsys):
    from repro.obs.export import main
    path = os.path.join(tmp_path, "t.jsonl")
    sess = TraceSession("cli", jsonl_path=path)
    with sess.span("request"):
        sess.emit("dispatch", "d", dur_s=1e-3)
    sess.close()
    out = os.path.join(tmp_path, "out.json")
    assert main([path, "-o", out]) == 0
    assert "perfetto" in capsys.readouterr().out
    assert json.load(open(out))["traceEvents"]


# -- MetricsStore ------------------------------------------------------------

def test_metrics_store_append_read_and_latest(tmp_path):
    store = MetricsStore(root=str(tmp_path / "m"))
    store.append("bench", {"x": 1.0}, run_id="r1", ts=10.0)
    store.append("bench", {"x": 2.0}, run_id="r2", ts=20.0)
    store.append("other", {"y": 5}, run_id="r1", ts=15.0)
    assert store.kinds() == ["bench", "other"]
    recs = store.records("bench")
    assert [r.run_id for r in recs] == ["r1", "r2"]   # append order
    assert recs[0].git_sha                            # stamped
    assert store.latest("bench").metrics == {"x": 2.0}
    assert [r.run_id for r in store.records("bench", since=15.0)] == ["r2"]
    assert [r.run_id for r in store.records("bench", run_id="r1")] == ["r1"]


def test_metrics_store_tolerates_truncated_trailing_line(tmp_path):
    store = MetricsStore(root=str(tmp_path / "m"))
    store.append("bench", {"x": 1.0}, run_id="r1")
    with open(store._path("bench"), "a") as f:
        f.write('{"run_id": "r2", "ts": 1.0, "kin')   # crashed writer
    assert [r.run_id for r in store.records("bench")] == ["r1"]
    # ...but corruption with valid records AFTER it still raises
    with open(store._path("bench"), "a") as f:
        f.write("\n")
        f.write(json.dumps(store.append("bench", {"x": 3.0},
                                        run_id="r3").to_dict()) + "\n")
    with pytest.raises((json.JSONDecodeError, KeyError)):
        store.records("bench")


def test_metrics_store_trend_and_cli(tmp_path, capsys):
    from repro.obs.store import main
    root = str(tmp_path / "m")
    store = MetricsStore(root=root)
    store.append("loadtest", {"latency_p50_s": 0.2, "tokens_per_s": 500.0},
                 run_id="r1", ts=100.0)
    store.append("loadtest", {"latency_p50_s": 0.1, "tokens_per_s": 900.0},
                 run_id="r2", ts=200.0)
    table = store.trend("loadtest")
    assert "latency_p50_s" in table and "r1" in table and "r2" in table
    md = store.trend("loadtest", markdown=True)
    assert md.startswith("| run_id |")
    assert main(["--root", root, "list"]) == 0
    assert "loadtest: 2 record(s)" in capsys.readouterr().out
    assert main(["--root", root, "trend", "--kind", "loadtest"]) == 0
    assert main(["--root", root, "show", "r1"]) == 0
    assert main(["--root", root, "show", "nope"]) == 1


# -- trajectory: count gating + store source --------------------------------

def test_is_count_metric_split():
    assert is_count_metric("graphs/name=replay/doorbells")
    assert is_count_metric("serve.request/payload_bytes")
    assert is_count_metric("loadtest/mode=cb_T4/tok_per_doorbell")
    assert not is_count_metric("loadtest/mode=cb_T4/p50_ms")
    assert not is_count_metric("session/total_dispatch_s")
    assert not is_count_metric("dma/name=inline/bandwidth_gib_s")


def _bench_artifact(path, doorbells, us):
    art = {"pr": 1, "quick": True,
           "sections": {"graphs": {"title": "g", "header": [],
                        "rows": [{"name": "replay", "chain_len": 8,
                                  "doorbells": doorbells,
                                  "launch_us": us}]}}}
    with open(path, "w") as f:
        json.dump(art, f)
    return str(path)


def test_trajectory_gate_counts_enforces_under_warn_only(tmp_path):
    from repro.obs.trajectory import main
    base = _bench_artifact(tmp_path / "BENCH_1.json", doorbells=8, us=100.0)
    # timing-only regression: warn-only stays green even with --gate-counts
    cand_t = _bench_artifact(tmp_path / "BENCH_2.json", doorbells=8,
                             us=500.0)
    assert main(["--baseline", base, "--candidate", cand_t,
                 "--warn-only", "--gate-counts"]) == 0
    # count regression: --gate-counts turns warn-only red...
    cand_c = _bench_artifact(tmp_path / "BENCH_3.json", doorbells=80,
                             us=100.0)
    assert main(["--baseline", base, "--candidate", cand_c,
                 "--warn-only", "--gate-counts"]) == 1
    # ...while plain --warn-only still waves it through
    assert main(["--baseline", base, "--candidate", cand_c,
                 "--warn-only"]) == 0


def test_trajectory_store_mode(tmp_path, capsys):
    from repro.obs.trajectory import main
    root = str(tmp_path / "m")
    store = MetricsStore(root=root)
    store.append("loadtest", {"doorbells": 10, "latency_p50_s": 0.1},
                 run_id="old")
    store.append("loadtest", {"doorbells": 30, "latency_p50_s": 0.1},
                 run_id="new")
    assert main(["--store", "loadtest", "--store-root", root]) == 1
    out = capsys.readouterr().out
    assert "COUNT REGRESSION" in out and "doorbells" in out
    assert main(["--store", "loadtest", "--store-root", root,
                 "--warn-only"]) == 0
    assert main(["--store", "missing", "--store-root", root]) == 2
