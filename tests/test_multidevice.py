"""Multi-device correctness (subprocess with 8 forced host devices).

The main pytest process must see ONE device (smoke tests / benches), so the
shard_map MoE and pipeline-decode equivalence checks run in a child python
with XLA_FLAGS=--xla_force_host_platform_device_count=8.
"""
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _run(code: str, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env, cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


@pytest.mark.slow
def test_moe_smap_matches_sorted_on_mesh():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import SMOKE_ARCHS
        from repro.models.moe import init_moe, moe_sorted, moe_sorted_smap
        from repro.distributed.context import set_mesh
        from repro.launch.mesh import make_mesh
        mesh = make_mesh(2, 4)
        set_mesh(mesh, ("data",))
        cfg = dataclasses.replace(SMOKE_ARCHS["qwen2-moe-a2.7b"],
                                  d_ff=32, capacity_factor=2.0)
        key = jax.random.PRNGKey(0)
        p = init_moe(key, cfg, jnp.float32)
        x = jax.random.normal(key, (4, 32, cfg.d_model), jnp.float32)
        with mesh:
            y1, _ = jax.jit(lambda p, x: moe_sorted(p, cfg, x))(p, x)
            y2, _ = jax.jit(lambda p, x: moe_sorted_smap(p, cfg, x))(p, x)
        np.testing.assert_allclose(np.asarray(y2), np.asarray(y1),
                                   rtol=2e-4, atol=2e-4)
        print("smap OK")
    """))


@pytest.mark.slow
def test_pp_decode_matches_sequential():
    print(_run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs.base import ModelConfig
        from repro.models import get_model
        from repro.distributed.pp_decode import PPDecoder
        from repro.launch.mesh import make_mesh
        cfg = ModelConfig(name="t", family="dense", n_layers=4, d_model=32,
                          n_heads=4, n_kv_heads=2, head_dim=8, d_ff=64,
                          vocab_size=64, param_dtype="float32", remat=False,
                          attn_chunk=0, loss_chunk=16)
        B, S_max, n_steps, T = 4, 32, 3, 2
        model = get_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (n_steps, B, T),
                                  0, cfg.vocab_size)
        state = model.init_decode_state(B, S_max)
        dec = jax.jit(model.decode_step)
        ref = []
        for n in range(n_steps):
            per_t = []
            for j in range(T):
                state, lg = dec(params, state, toks[n][:, j:j+1])
                per_t.append(np.asarray(lg, np.float32)[:, 0])
            ref.append(np.stack(per_t, axis=1))
        mesh = make_mesh(2, 2)
        pp = PPDecoder(cfg, mesh, tokens_per_launch=T)
        ns, lps = pp.n_stages, pp.layers_per_stage
        pp_params = {"emb": params["emb"],
                     "layers": jax.tree_util.tree_map(
                         lambda a: a.reshape((ns, lps) + a.shape[1:]),
                         params["layers"]),
                     "final_norm": params["final_norm"],
                     "valid": jnp.ones((ns, lps), bool)}
        pp_state = pp.init_state(B, S_max)
        step = pp.make_step(B, S_max)
        out = []
        with mesh:
            jstep = jax.jit(step)
            for n in range(n_steps):
                pp_state, lg = jstep(pp_params, pp_state, toks[n])
                out.append(np.asarray(lg, np.float32))
        mb = B // ns
        for n in range(n_steps):
            for u in range(ns):
                lag = (u + ns - 1) // ns
                if n + lag >= n_steps:
                    continue
                np.testing.assert_allclose(
                    out[n+lag][u*mb:(u+1)*mb], ref[n][u*mb:(u+1)*mb],
                    rtol=3e-4, atol=3e-4)
        print("pp OK")
    """))


@pytest.mark.slow
def test_dryrun_smoke_cell_compiles():
    """One tiny production-style lower+compile on an 8-device mesh."""
    print(_run("""
        import jax
        from repro.configs import SMOKE_ARCHS
        from repro.configs.shapes import ShapeConfig
        from repro.models import get_model
        from repro.runtime.steps import make_train_step, init_all, make_input_specs
        from repro.distributed.sharding import ShardingRules
        from repro.launch.mesh import make_mesh
        from repro.core import CommandStreamCapture
        cfg = SMOKE_ARCHS["qwen3-8b"]
        model = get_model(cfg)
        mesh = make_mesh(2, 4)
        rules = ShardingRules(mesh, cfg)
        shape = ShapeConfig("t", 64, 8, "train")
        batch = make_input_specs(cfg, shape)
        params_s, opt_s = jax.eval_shape(lambda: init_all(model, cfg))
        cap = CommandStreamCapture()
        with mesh:
            cs = cap.lower_and_compile(
                "t", make_train_step(model, cfg),
                args=(params_s, opt_s, batch),
                in_shardings=(rules.to_shardings(rules.param_specs(params_s)),
                              rules.to_shardings(rules.opt_specs(opt_s)),
                              rules.to_shardings(rules.data_specs(batch))))
        assert cs.flops > 0 and cs.collective_link_bytes > 0
        assert not cs.stream.unknown_trip_counts
        print("dryrun-cell OK, flops", cs.flops)
    """))
