"""Distributed paths that run on a single device: smap MoE fallback,
triangle attention equivalence, PP decode schedule math, elastic planning.

(The multi-device shard_map/PP correctness tests live in
``tests/test_multidevice.py`` and run in a subprocess with 8 fake devices —
the main pytest process must keep the default single-device backend.)
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SMOKE_ARCHS
from repro.models.attention import (dense_causal_attention,
                                    triangle_chunked_attention)
from repro.models.moe import init_moe, moe_sorted, moe_sorted_smap

rng = np.random.default_rng(11)
KEY = jax.random.PRNGKey(0)


def test_triangle_attention_matches_dense():
    B, S, H, hd = 2, 256, 3, 32
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)).astype(np.float32))
    ref = dense_causal_attention(q, k, v, causal=True)
    for chunk in (32, 64, 128):
        out = triangle_chunked_attention(q, k, v, chunk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=3e-5, atol=3e-5)


def test_triangle_attention_odd_chunks_falls_back():
    B, S, H, hd = 1, 96, 2, 16   # n = 3 (odd) -> masked fallback
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)).astype(np.float32))
    out = triangle_chunked_attention(q, q, q, 32)
    ref = dense_causal_attention(q, q, q, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)


def test_triangle_attention_halves_flops():
    from repro.core import capture_fn
    from repro.models.attention import chunked_causal_attention
    spec = jax.ShapeDtypeStruct((1, 2048, 2, 64), jnp.bfloat16)
    a = capture_fn(lambda q, k, v: chunked_causal_attention(q, k, v, 256),
                   spec, spec, spec)
    b = capture_fn(lambda q, k, v: triangle_chunked_attention(q, k, v, 256),
                   spec, spec, spec)
    assert b.flops / a.flops < 0.62          # (n+1)/2n + eps, n=8


def test_moe_smap_falls_back_without_mesh():
    from repro.distributed import context
    context.set_mesh(None, ())
    cfg = dataclasses.replace(SMOKE_ARCHS["qwen2-moe-a2.7b"],
                              n_shared_experts=0, capacity_factor=2.0)
    p = init_moe(KEY, cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)).astype(np.float32))
    y1, _ = moe_sorted(p, cfg, x)
    y2, _ = moe_sorted_smap(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y1),
                               rtol=1e-5, atol=1e-5)


def test_pp_decoder_schedule_math():
    """Stage/µbatch bookkeeping invariants (no devices needed)."""
    from repro.launch.mesh import SINGLE_POD
    n_stages = SINGLE_POD[0]
    n_micro = n_stages
    served = {}
    for t in range(n_micro):
        for s in range(n_stages):
            mb = (t - s) % n_micro
            served.setdefault(s, []).append(mb)
    for s, mbs in served.items():
        assert sorted(mbs) == list(range(n_micro))  # every stage: all µbs
    # µb m reaches stage s at tick (m+s) mod n_micro, wrapped iff m+s >= n
    for m in range(n_micro):
        for s in range(n_stages):
            t = (m + s) % n_micro
            assert (t - s) % n_micro == m
            assert (t < s) == (m + s >= n_micro)   # the pos_tok offset rule
