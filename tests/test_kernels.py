"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.kernels.dma_copy.ops import dma_copy
from repro.kernels.dma_copy.ref import dma_copy_ref

rng = np.random.default_rng(42)


# ---------------------------------------------------------------- flash
@pytest.mark.parametrize("B,S,H,hd", [
    (1, 128, 1, 64), (2, 256, 4, 64), (1, 256, 2, 128), (1, 128, 2, 256),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, S, H, hd, causal, dtype):
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), dtype)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), dtype)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), dtype)
    out = flash_attention(q, k, v, causal=causal)
    ref = flash_attention_ref(q, k, v, causal=causal)
    tol = 3e-2 if dtype == jnp.bfloat16 else 3e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_block_shapes():
    q = jnp.asarray(rng.normal(size=(1, 512, 2, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 512, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 512, 2, 64)), jnp.float32)
    ref = flash_attention_ref(q, k, v, causal=True)
    for bq, bk in [(64, 128), (128, 64), (256, 256)]:
        out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=3e-5, atol=3e-5)


# ---------------------------------------------------------------- ssd
@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 64, 2, 16, 8, 16), (2, 128, 4, 32, 16, 32),
    (1, 256, 8, 64, 128, 64), (1, 128, 3, 16, 8, 128),
])
def test_ssd_scan_sweep(B, S, H, P, N, chunk):
    xh = jnp.asarray(rng.normal(size=(B, S, H, P)).astype(np.float32))
    dt = jnp.asarray(np.abs(rng.normal(size=(B, S, H))).astype(np.float32))
    A = jnp.asarray(-np.abs(rng.normal(size=(H,))).astype(np.float32))
    Bc = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    Cc = jnp.asarray(rng.normal(size=(B, S, N)).astype(np.float32))
    y, _ = ssd_scan(xh, dt, A, Bc, Cc, chunk=min(chunk, S))
    y_ref, _ = ssd_scan_ref(xh, dt, A, Bc, Cc, chunk=min(chunk, S))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-3, atol=1e-3)


def test_ssd_scan_bf16():
    B, S, H, P, N = 1, 64, 2, 16, 8
    xh = jnp.asarray(rng.normal(size=(B, S, H, P)), jnp.bfloat16)
    dt = jnp.asarray(np.abs(rng.normal(size=(B, S, H))).astype(np.float32))
    A = jnp.asarray(-np.abs(rng.normal(size=(H,))).astype(np.float32))
    Bc = jnp.asarray(rng.normal(size=(B, S, N)), jnp.bfloat16)
    Cc = jnp.asarray(rng.normal(size=(B, S, N)), jnp.bfloat16)
    y, _ = ssd_scan(xh, dt, A, Bc, Cc, chunk=16)
    y_ref, _ = ssd_scan_ref(xh, dt, A, Bc, Cc, chunk=16)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=6e-2, atol=6e-2)


# ---------------------------------------------------------------- dma
@pytest.mark.parametrize("mode", ["pipelined", "explicit"])
@pytest.mark.parametrize("R,C,blk", [(256, 64, 64), (1024, 128, 256),
                                     (128, 32, 128)])
def test_dma_copy_sweep(mode, R, C, blk):
    x = jnp.asarray(rng.normal(size=(R, C)).astype(np.float32))
    y = dma_copy(x, mode=mode, block_rows=blk)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(dma_copy_ref(x)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
def test_dma_copy_dtypes(dtype):
    x = jnp.asarray(rng.integers(-100, 100, size=(256, 128)), dtype)
    y = dma_copy(x, mode="pipelined", block_rows=64)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


# ---------------------------------------------------------------- rms_norm
from repro.kernels.rms_norm.ops import rms_norm_fused
from repro.kernels.rms_norm.ref import rms_norm_ref


@pytest.mark.parametrize("shape", [(4, 64, 256), (2, 100, 128), (1, 7, 512),
                                   (8, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rms_norm_fused_sweep(shape, dtype):
    x = jnp.asarray(rng.normal(size=shape), dtype)
    s = jnp.asarray(rng.normal(size=shape[-1:]) * 0.1, dtype)
    out = rms_norm_fused(x, s)
    ref = rms_norm_ref(x, s)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_rms_norm_matches_model_layer():
    from repro.models.layers import rms_norm as model_rms
    x = jnp.asarray(rng.normal(size=(3, 17, 64)).astype(np.float32))
    s = jnp.asarray(rng.normal(size=(64,)).astype(np.float32) * 0.1)
    out = rms_norm_fused(x, s)
    ref = model_rms({"scale": s}, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
