"""Integration: Trainer (ckpt/restart/multi-step launch) + Server."""
import tempfile

import numpy as np
import pytest

from repro.configs import SMOKE_ARCHS
from repro.configs.shapes import ShapeConfig
from repro.runtime.server import Request, Server
from repro.runtime.trainer import Trainer

CFG = SMOKE_ARCHS["deepseek-7b"]
SHAPE = ShapeConfig("tiny", 64, 4, "train")


def test_trainer_loss_decreases():
    tr = Trainer(CFG, SHAPE, peak_lr=1e-3)
    out = tr.train(8)
    losses = [m["loss"] for m in tr.metrics_log]
    assert out["steps"] == 8
    assert losses[-1] < losses[0] + 0.1  # not diverging
    assert all(np.isfinite(l) for l in losses)


def test_trainer_checkpoint_restart_exact():
    """Stop at step 4, restart, continue to 6 == straight run to 6."""
    with tempfile.TemporaryDirectory() as d:
        a = Trainer(CFG, SHAPE, ckpt_dir=d, ckpt_every=4, seed=9)
        a.train(4)
        b = Trainer(CFG, SHAPE, ckpt_dir=d, ckpt_every=4, seed=9)
        assert b.maybe_restore()
        assert b.step == 4
        b.train(6)
    c = Trainer(CFG, SHAPE, seed=9)
    c.train(6)
    assert b.metrics_log[-1]["loss"] == pytest.approx(
        c.metrics_log[-1]["loss"], rel=1e-4)


def test_multistep_launch_fewer_doorbells_same_result():
    a = Trainer(CFG, SHAPE, steps_per_launch=1, seed=5)
    oa = a.train(4)
    b = Trainer(CFG, SHAPE, steps_per_launch=4, seed=5)
    ob = b.train(4)
    assert oa["doorbells"] == 4 and ob["doorbells"] == 1
    assert ob["final_loss"] == pytest.approx(oa["final_loss"], rel=1e-3)


def test_grad_compression_trains():
    tr = Trainer(CFG, SHAPE, grad_compression="int8", peak_lr=1e-3)
    out = tr.train(4)
    assert np.isfinite(out["final_loss"])


def test_server_truncated_final_block_accounting():
    """Regression: T>1 with max_new not a multiple of T overcounted
    ``produced`` and inflated new_tokens/tokens_per_doorbell."""
    srv = Server(CFG, batch_size=2, max_seq=64, tokens_per_launch=4, seed=1)
    reqs = [Request(i, np.arange(4, dtype=np.int32) + i, max_new_tokens=6)
            for i in range(2)]
    out = srv.serve(reqs)
    # 1 prefill + ceil((6-1)/4)=2 decode launches
    assert out["doorbells"] == 3
    assert out["new_tokens"] == 12                 # sum of request budgets
    assert out["tokens_per_doorbell"] == pytest.approx(4.0)
    assert all(len(r.tokens) == 6 for r in reqs)


def test_server_heterogeneous_budgets_sum_not_max():
    """Regression: new_tokens used max_new * len(requests); must be the sum
    of per-request budgets — the tuner objective reads these fields."""
    srv = Server(CFG, batch_size=2, max_seq=64, tokens_per_launch=2, seed=1)
    reqs = [Request(0, np.arange(4, dtype=np.int32), max_new_tokens=8),
            Request(1, np.arange(4, dtype=np.int32) + 1, max_new_tokens=2)]
    out = srv.serve(reqs)
    assert out["new_tokens"] == 10                 # not 16
    assert out["tokens_per_doorbell"] == pytest.approx(
        10 / out["doorbells"])
    assert len(reqs[0].tokens) == 8 and len(reqs[1].tokens) == 2


def test_server_rejects_prompt_longer_than_max_seq():
    srv = Server(CFG, batch_size=2, max_seq=16, tokens_per_launch=1, seed=1)
    with pytest.raises(ValueError, match="max_seq"):
        srv.serve([Request(0, np.zeros(17, np.int32))])


def test_server_greedy_decode_and_doorbell_economy():
    srv1 = Server(CFG, batch_size=2, max_seq=64, tokens_per_launch=1, seed=1)
    srv4 = Server(CFG, batch_size=2, max_seq=64, tokens_per_launch=4, seed=1)
    mk = lambda: [Request(i, np.arange(4, dtype=np.int32) + i,
                          max_new_tokens=8) for i in range(2)]
    r1, r4 = mk(), mk()
    o1 = srv1.serve(r1)
    o4 = srv4.serve(r4)
    assert o4["doorbells"] < o1["doorbells"]
    # same greedy tokens either way
    assert [r.tokens for r in r1] == [r.tokens for r in r4]
