"""Inline vs direct data-movement protocols (paper §6.2 analogue)."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import (HybridMover, INLINE_THRESHOLD_DEFAULT, direct_put,
                        inline_put, sweep_transfer)
from repro.core.dma import _fingerprint

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def test_inline_put_roundtrip():
    x = np.arange(512, dtype=np.float32)
    y, rec = inline_put(x)
    np.testing.assert_array_equal(np.asarray(y), x)
    assert rec.mode == "inline"
    assert rec.nbytes == x.nbytes


def test_direct_put_roundtrip():
    x = np.arange(4096, dtype=np.int32)
    y, rec = direct_put(x)
    np.testing.assert_array_equal(np.asarray(y), x)
    assert rec.mode == "direct"


def test_hybrid_mover_threshold_switch():
    mover = HybridMover(threshold=1024)
    _, small = mover.put(np.zeros(16, np.float32))      # 64 B
    _, large = mover.put(np.zeros(4096, np.float32))    # 16 KiB
    assert small.mode == "inline"
    assert large.mode == "direct"
    assert mover.stats() == {"inline": 1, "direct": 1}


def test_threshold_is_tunable_unlike_cuda():
    """The paper (§7): CUDA's protocol switch is opaque; ours is a knob."""
    always_direct = HybridMover(threshold=0)
    _, rec = always_direct.put(np.zeros(4, np.uint8))
    assert rec.mode == "direct"
    always_inline = HybridMover(threshold=1 << 40)
    _, rec = always_inline.put(np.zeros(1 << 16, np.uint8))
    assert rec.mode == "inline"
    assert INLINE_THRESHOLD_DEFAULT == 24 * 1024  # the paper's switch point


def test_hybrid_mover_direct_at_exact_threshold():
    """The switch is direct at nbytes == threshold (inline strictly below)."""
    mover = HybridMover(threshold=1024)
    _, below = mover.put(np.zeros(1023, np.uint8))
    _, at = mover.put(np.zeros(1024, np.uint8))
    assert below.mode == "inline"
    assert at.mode == "direct"


def test_fingerprint_is_content_digest():
    x = np.arange(16, dtype=np.int32)
    assert _fingerprint(x) == _fingerprint(x.copy())
    assert _fingerprint(x) != _fingerprint(x + 1)
    assert _fingerprint(x) != _fingerprint(x.astype(np.int64))


@pytest.mark.slow
def test_fingerprint_stable_across_processes():
    """Regression: the cache key used salted hash(); it must be identical
    under different PYTHONHASHSEED so it can persist alongside policies."""
    code = textwrap.dedent("""
        import numpy as np
        from repro.core.dma import _fingerprint
        print(_fingerprint(np.arange(256, dtype=np.float32)))
    """)
    outs = []
    for seed in ("0", "12345"):
        env = dict(os.environ, PYTHONHASHSEED=seed,
                   PYTHONPATH=os.path.join(ROOT, "src"))
        r = subprocess.run([sys.executable, "-c", code], env=env,
                           capture_output=True, text=True, timeout=120)
        assert r.returncode == 0, r.stderr[-2000:]
        outs.append(r.stdout.strip())
    assert outs[0] == outs[1] != ""


@pytest.mark.slow
def test_inline_put_honors_device():
    """Regression: the inline path ignored ``device``, so HybridMover
    silently mis-placed small transfers on the default device."""
    code = textwrap.dedent("""
        import jax, numpy as np
        from repro.core.dma import HybridMover, inline_put
        d1 = jax.devices()[1]
        y, rec = inline_put(np.arange(64, dtype=np.float32), device=d1)
        assert rec.mode == "inline"
        assert y.devices() == {d1}, y.devices()
        # cache must not serve a device-0 executable for a device-1 put
        y0, _ = inline_put(np.arange(64, dtype=np.float32))
        assert y0.devices() == {jax.devices()[0]}, y0.devices()
        mover = HybridMover(threshold=1 << 20, device=d1)
        ym, recm = mover.put(np.zeros(128, np.float32))
        assert recm.mode == "inline" and ym.devices() == {d1}
        print("ok")
    """)
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=2",
               PYTHONPATH=os.path.join(ROOT, "src"))
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=300)
    assert r.returncode == 0, r.stderr[-3000:]
    assert r.stdout.strip() == "ok"


def test_sweep_shapes():
    out = sweep_transfer([64, 1024], mode="direct", iters=3, warmup=1)
    assert [r["nbytes"] for r in out] == [64, 1024]
    assert all(r["latency_us"] > 0 for r in out)
