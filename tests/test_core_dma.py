"""Inline vs direct data-movement protocols (paper §6.2 analogue)."""
import numpy as np
import pytest

from repro.core import (HybridMover, INLINE_THRESHOLD_DEFAULT, direct_put,
                        inline_put, sweep_transfer)


def test_inline_put_roundtrip():
    x = np.arange(512, dtype=np.float32)
    y, rec = inline_put(x)
    np.testing.assert_array_equal(np.asarray(y), x)
    assert rec.mode == "inline"
    assert rec.nbytes == x.nbytes


def test_direct_put_roundtrip():
    x = np.arange(4096, dtype=np.int32)
    y, rec = direct_put(x)
    np.testing.assert_array_equal(np.asarray(y), x)
    assert rec.mode == "direct"


def test_hybrid_mover_threshold_switch():
    mover = HybridMover(threshold=1024)
    _, small = mover.put(np.zeros(16, np.float32))      # 64 B
    _, large = mover.put(np.zeros(4096, np.float32))    # 16 KiB
    assert small.mode == "inline"
    assert large.mode == "direct"
    assert mover.stats() == {"inline": 1, "direct": 1}


def test_threshold_is_tunable_unlike_cuda():
    """The paper (§7): CUDA's protocol switch is opaque; ours is a knob."""
    always_direct = HybridMover(threshold=0)
    _, rec = always_direct.put(np.zeros(4, np.uint8))
    assert rec.mode == "direct"
    always_inline = HybridMover(threshold=1 << 40)
    _, rec = always_inline.put(np.zeros(1 << 16, np.uint8))
    assert rec.mode == "inline"
    assert INLINE_THRESHOLD_DEFAULT == 24 * 1024  # the paper's switch point


def test_sweep_shapes():
    out = sweep_transfer([64, 1024], mode="direct", iters=3, warmup=1)
    assert [r["nbytes"] for r in out] == [64, 1024]
    assert all(r["latency_us"] > 0 for r in out)
