"""Property-based tests (hypothesis) on fleet aggregation invariants.

Kept separate from test_obs.py: the module-level importorskip below skips
this whole file when hypothesis is absent (it is in requirements-dev.txt,
so CI always runs it).
"""
import json
import os
import random
import tempfile

import pytest

from repro.core import TraceEvent
from repro.obs import aggregate

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402


@settings(max_examples=30, deadline=None)
@given(
    true_times=st.lists(st.floats(0.001, 100.0, allow_nan=False,
                                  allow_infinity=False),
                        min_size=1, max_size=40),
    skews=st.tuples(st.floats(-50.0, 50.0), st.floats(-50.0, 50.0)),
    assign=st.lists(st.integers(0, 1), min_size=1, max_size=40),
    shuffle_seed=st.integers(0, 2 ** 16),
)
def test_aggregate_property_monotonic_and_remerge_stable(
        true_times, skews, assign, shuffle_seed):
    """Shuffled multi-shard inputs with arbitrary clock skews merge into a
    timeline monotonic in the aligned clock, stable under re-merge."""
    n = min(len(true_times), len(assign))
    true_times, assign = sorted(true_times[:n]), assign[:n]
    with tempfile.TemporaryDirectory() as d:
        paths = []
        for p in (0, 1):
            events = [TraceEvent(seq=0, kind="progress", name="obs.barrier",
                                 t=0.0 - skews[p],
                                 meta={"process": p, "barrier": "b0"})]
            for tt, a in zip(true_times, assign):
                if a == p:
                    events.append(TraceEvent(
                        seq=0, kind="dispatch", name=f"e{tt}",
                        t=tt - skews[p], meta={"process": p}))
            events.sort(key=lambda e: e.t)
            events = [TraceEvent(seq=i, kind=e.kind, name=e.name, t=e.t,
                                 meta=e.meta)
                      for i, e in enumerate(events)]
            random.Random(shuffle_seed + p).shuffle(events)
            path = os.path.join(d, f"s{p}.jsonl")
            with open(path, "w") as f:
                for e in events:
                    f.write(json.dumps(e.to_dict()) + "\n")
            paths.append(path)
        merged = aggregate(paths)
        ts = [e.t for e in merged.events]
        assert ts == sorted(ts)                     # monotonic aligned clock
        assert len(merged.events) == n + 2
        # both barriers coincide after alignment (up to float noise)
        bs = [e.t for e in merged.events if e.name == "obs.barrier"]
        assert abs(bs[0] - bs[1]) < 1e-6
        # re-merge of the merged output is a fixed point
        out = os.path.join(d, "m.jsonl")
        merged.save(out)
        again = aggregate([out])
        assert [(e.seq, e.name) for e in again.events] == \
            [(e.seq, e.name) for e in merged.events]
