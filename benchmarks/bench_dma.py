"""Paper Figure 6 + Table 2 analogue: inline vs direct data movement.

Sweeps transfer size over both protocols and reports:
  * latency (µs) and bandwidth (GiB/s) per size — Fig. 6;
  * the submit-vs-complete split (dispatch boundary vs engine completion),
    the analogue of Table 2's Nsight-vs-raw decomposition: ``overhead_pct``
    is the fraction of end-to-end latency not explained by the payload
    movement itself (measured at the smallest size as the per-call floor).

Transfers report into the ambient :class:`repro.core.TraceSession` (the
harness in ``run.py`` installs one), so every put lands on the unified
submission timeline alongside the other sections' events.
"""
from __future__ import annotations

from typing import List

from repro.core import current_session
from repro.core.dma import sweep_transfer

EXP_SIZES = [4 * (2 ** i) for i in range(13)]          # 4 B .. 16 KiB
LIN_SIZES = [1024 * i for i in range(1, 32, 3)]        # 1 KiB .. 31 KiB
LARGE_SIZES = [32 * 1024, 128 * 1024, 512 * 1024,
               2 * 2**20, 8 * 2**20, 32 * 2**20]       # Table 2 right half


def run(quick: bool = False) -> List[str]:
    """``quick`` shrinks sweeps to CI scale (fewer sizes, fewer iters)."""
    rows: List[str] = []
    exp_sizes = EXP_SIZES[::3] if quick else EXP_SIZES
    iters = 3 if quick else 10
    for mode in ("inline", "direct"):
        sweep = sweep_transfer(exp_sizes, mode=mode, iters=iters, warmup=2)
        floor_us = sweep[0]["latency_us"]
        for r in sweep:
            overhead = 100.0 * min(1.0, floor_us / max(r["latency_us"], 1e-9))
            rows.append(
                f"dma_{mode}_exp,{r['nbytes']},{r['latency_us']:.2f},"
                f"{r['bandwidth_gib_s']:.3f},{overhead:.1f}")
    if not quick:
        for mode in ("inline", "direct"):
            for r in sweep_transfer(LIN_SIZES, mode=mode, iters=5, warmup=2):
                rows.append(
                    f"dma_{mode}_lin,{r['nbytes']},{r['latency_us']:.2f},"
                    f"{r['bandwidth_gib_s']:.3f},")
    large = LARGE_SIZES[:3] if quick else LARGE_SIZES
    for r in sweep_transfer(large, mode="direct", iters=3 if quick else 5,
                            warmup=2):
        rows.append(
            f"dma_direct_large,{r['nbytes']},{r['latency_us']:.2f},"
            f"{r['bandwidth_gib_s']:.3f},")
    sess = current_session()
    if sess is not None:
        rows.append(
            f"dma_trace_events,{len(sess.timeline(kinds='transfer'))},,,")
    return rows


HEADER = "name,nbytes,latency_us,bandwidth_gib_s,overhead_pct"
