"""Tuned-policy before/after: the autotuner's win, measured end to end.

If a persisted policy exists for ``--arch`` (``python -m repro.tune`` writes
one under ``results/policies``), this section re-measures the serve workload
at the default knobs and at the tuned knobs on this machine, and reports
both objective scores — the closed loop the paper's §7 asks for: the
threshold is exposed, measured, chosen, and the choice is auditable.

Reuses the tuner's own :class:`~repro.tune.autotune.CandidateEvaluator`, so
the numbers here are computed exactly the way the search scored candidates.
"""
from __future__ import annotations

from typing import List, Optional

from repro.core import TraceSession
from repro.tune.autotune import CandidateEvaluator, WorkloadSpec
from repro.tune.objective import Objective
from repro.tune.policy import load_policy

HEADER = "name,score_s_per_token,doorbells_per_token,dispatch_ms,tokens"


def run(arch: str = "gemma-2b", quick: bool = False,
        session: Optional[TraceSession] = None) -> List[str]:
    from repro.configs import SMOKE_ARCHS
    cfg = SMOKE_ARCHS[arch]
    pol = load_policy(cfg.name)       # policies are keyed by cfg.name
    if pol is None:
        return [f"policy_none,{arch},,,"]
    obj = Objective()
    spec = WorkloadSpec(new_tokens=4 if quick else 8,
                        train_steps=4 if quick else 8)
    ev = CandidateEvaluator(cfg, spec=spec, objective=obj,
                            workloads=("serve",))
    rows: List[str] = []
    for label, tpl in (("baseline", 1),
                       ("tuned", int(pol.knob("tokens_per_launch", 1)))):
        m = ev.measure("serve", {"tokens_per_launch": tpl})
        rows.append(f"policy_serve_{label},{obj.score(m):.3e},"
                    f"{m.doorbells_per_token:.3f},"
                    f"{m.dispatch_s * 1e3:.2f},{m.tokens}")
    rows.append(f"policy_objective_recorded_before,"
                f"{pol.objective.get('before', '')},,,")
    rows.append(f"policy_objective_recorded_after,"
                f"{pol.objective.get('after', '')},,,")
    if session is not None:
        session.emit("progress", "policy_bench", knobs=pol.knobs)
    return rows
