"""Benchmark harness: one section per paper table/figure, JSON artifact out.

  bench_dma        — Fig. 6 + Table 2 (inline vs direct DMA protocols)
  bench_graphs     — Fig. 7/9/10 (graph launch scaling, footprint law)
  bench_submission — §6.2/§7 (stage decomposition, multi-step economy)
  bench_policy     — tuned-policy before/after (python -m repro.tune)
  bench_loadtest   — continuous-batching serve under Poisson traffic
  bench_kv         — dense vs paged KV backends on shared-prefix traffic
  bench_kernels    — per-kernel interpret-mode sanity timings

Prints ``name,value...`` CSV blocks (unchanged), and additionally writes a
machine-readable artifact (``--out``, default ``BENCH_10.json``) recording
section -> rows (typed by the section header), the unified TraceSession
summary, and the active tuned policy with its before/after objective — one
point of the ROADMAP's perf trajectory, regenerated per PR and gated in CI
by ``python -m repro.obs.trajectory`` against the newest committed
``BENCH_*.json`` (deterministic count metrics gate hard via
``--gate-counts``; timings stay warn-only on shared runners).  The scored
metrics are also appended to the persistent store
(``results/metrics/bench.jsonl``; disable with ``--no-store``) so
``python -m repro.obs.store trend --kind bench`` answers across runs.
``--quick`` shrinks every sweep to CI scale.

ONE :class:`repro.core.TraceSession` spans every section — installed as the
ambient session and passed explicitly where a section builds its own objects
— so the final block is the unified, submission-ordered event summary across
DMA, graph-launch, trainer, and policy benchmarks.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--out BENCH_10.json]
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Any, Dict, List

PR_NUMBER = 10


def _parse_cell(v: str) -> Any:
    if v == "":
        return None
    try:
        return int(v)
    except ValueError:
        pass
    try:
        return float(v)
    except ValueError:
        return v


def _rows_to_json(header: str, rows: List[str]) -> List[Dict[str, Any]]:
    """CSV rows -> list of {column: typed value} dicts, keyed by header."""
    cols = header.split(",")
    out = []
    for r in rows:
        cells = r.split(",")
        cells += [""] * (len(cols) - len(cells))
        out.append({c: _parse_cell(v) for c, v in zip(cols, cells)})
    return out


def bench_kernels_rows():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.ssd_scan.ops import ssd_scan
    rows = []
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 256, 4, 64)), jnp.float32)
    t0 = time.perf_counter()
    jax.block_until_ready(flash_attention(q, q, q))
    rows.append(f"flash_attention_interp_256,{(time.perf_counter()-t0)*1e3:.1f}")
    xh = jnp.asarray(rng.normal(size=(1, 256, 4, 32)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(1, 256, 4))), jnp.float32)
    A = jnp.asarray(-np.ones(4), jnp.float32)
    Bc = jnp.asarray(rng.normal(size=(1, 256, 16)), jnp.float32)
    t0 = time.perf_counter()
    y, _ = ssd_scan(xh, dt, A, Bc, Bc, chunk=64)
    jax.block_until_ready(y)
    rows.append(f"ssd_scan_interp_256,{(time.perf_counter()-t0)*1e3:.1f}")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=f"BENCH_{PR_NUMBER}.json",
                    help="JSON artifact path ('' to skip writing)")
    ap.add_argument("--quick", action="store_true",
                    help="CI-scale sweeps (fewer sizes/chains/steps)")
    ap.add_argument("--arch", default="gemma-2b",
                    help="arch whose tuned policy the policy section benches")
    ap.add_argument("--no-store", action="store_true",
                    help="skip appending scored metrics to the persistent "
                         "metrics store (results/metrics/bench.jsonl)")
    args = ap.parse_args()

    from repro.core import TraceSession
    from repro.tune.policy import load_policy

    from . import (bench_dma, bench_graphs, bench_kv, bench_loadtest,
                   bench_policy, bench_submission)

    sections: Dict[str, Dict[str, Any]] = {}

    def _section(key: str, title: str, header: str, rows: List[str]) -> None:
        print(f"# === {title} ===")
        print(header)
        for r in rows:
            print(r)
        sys.stdout.flush()
        sections[key] = {"title": title, "header": header.split(","),
                         "rows": _rows_to_json(header, rows)}

    with TraceSession(name="benchmarks") as sess:
        _section("dma", "DMA protocols (Fig.6 / Table 2)", bench_dma.HEADER,
                 bench_dma.run(quick=args.quick))
        _section("graphs", "Graph launch scaling (Fig.7/9/10)",
                 bench_graphs.HEADER,
                 bench_graphs.run(quick=args.quick, session=sess))
        _section("submission", "Submission stage split (§6.2/§7)",
                 bench_submission.HEADER,
                 bench_submission.run(quick=args.quick, session=sess))
        _section("policy", "Tuned submission policy (repro.tune)",
                 bench_policy.HEADER,
                 bench_policy.run(arch=args.arch, quick=args.quick,
                                  session=sess))
        _section("loadtest", "Continuous-batching serve (Poisson replay)",
                 bench_loadtest.HEADER,
                 bench_loadtest.run(arch=args.arch, quick=args.quick,
                                    session=sess))
        _section("kv", "KV backends: dense vs paged (shared-prefix)",
                 bench_kv.HEADER,
                 bench_kv.run(arch=args.arch, quick=args.quick,
                              session=sess))
        _section("kernels", "Kernel interpret-mode timings", "name,ms",
                 bench_kernels_rows())
    summary = sess.summary()
    sink_stats = sess.sink_stats()
    print("# === Unified trace session ===")
    print(json.dumps(summary, indent=2, sort_keys=True))

    if args.out:
        from repro.configs import SMOKE_ARCHS
        cfg = SMOKE_ARCHS.get(args.arch)
        pol = load_policy(getattr(cfg, "name", None) or args.arch)
        artifact = {
            "pr": PR_NUMBER,
            "created_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "quick": bool(args.quick),
            "arch": args.arch,
            "sections": sections,
            "session_summary": summary,
            "sink_stats": sink_stats,
            "policy": pol.to_dict() if pol is not None else None,
            "tuning": ({"before": pol.objective.get("before"),
                        "after": pol.objective.get("after"),
                        "improvement": pol.objective.get("improvement"),
                        "knobs": pol.knobs}
                       if pol is not None else None),
        }
        with open(args.out, "w") as f:
            json.dump(artifact, f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"# wrote {args.out}")

        if not args.no_store:
            # one trajectory point per run in the persistent store — the
            # same scored metrics the trajectory gate diffs, queryable
            # across runs with `python -m repro.obs.store trend --kind
            # bench` / `python -m repro.obs.trajectory --store bench`
            try:
                from repro.obs.store import MetricsStore
                from repro.obs.trajectory import extract_metrics
                scored = {k: v for k, (v, _d)
                          in extract_metrics(artifact).items()}
                rec = MetricsStore().append(
                    "bench", scored,
                    meta={"pr": PR_NUMBER, "quick": bool(args.quick),
                          "arch": args.arch, "out": args.out})
                print(f"# stored {len(scored)} metrics as run {rec.run_id}")
            except OSError as e:
                print(f"# metrics store unavailable ({e}); skipped")


if __name__ == "__main__":
    main()
