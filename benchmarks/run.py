"""Benchmark harness: one section per paper table/figure.

  bench_dma        — Fig. 6 + Table 2 (inline vs direct DMA protocols)
  bench_graphs     — Fig. 7/9/10 (graph launch scaling, footprint law)
  bench_submission — §6.2/§7 (stage decomposition, multi-step economy)
  bench_kernels    — per-kernel interpret-mode sanity timings

Prints ``name,value...`` CSV blocks.  Wall-clock numbers are host (CPU
container) figures; device-side terms come from the dry-run roofline
(EXPERIMENTS.md), not from here.

ONE :class:`repro.core.TraceSession` spans every section — installed as the
ambient session and passed explicitly where a section builds its own objects
— so the final block is the unified, submission-ordered event summary across
DMA, graph-launch, and trainer benchmarks.
"""
from __future__ import annotations

import json
import sys
import time


def _section(title: str, header: str, rows) -> None:
    print(f"# === {title} ===")
    print(header)
    for r in rows:
        print(r)
    sys.stdout.flush()


def bench_kernels_rows():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.ssd_scan.ops import ssd_scan
    rows = []
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(1, 256, 4, 64)), jnp.float32)
    t0 = time.perf_counter()
    jax.block_until_ready(flash_attention(q, q, q))
    rows.append(f"flash_attention_interp_256,{(time.perf_counter()-t0)*1e3:.1f}")
    xh = jnp.asarray(rng.normal(size=(1, 256, 4, 32)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(1, 256, 4))), jnp.float32)
    A = jnp.asarray(-np.ones(4), jnp.float32)
    Bc = jnp.asarray(rng.normal(size=(1, 256, 16)), jnp.float32)
    t0 = time.perf_counter()
    y, _ = ssd_scan(xh, dt, A, Bc, Bc, chunk=64)
    jax.block_until_ready(y)
    rows.append(f"ssd_scan_interp_256,{(time.perf_counter()-t0)*1e3:.1f}")
    return rows


def main() -> None:
    from repro.core import TraceSession

    from . import bench_dma, bench_graphs, bench_submission
    with TraceSession(name="benchmarks") as sess:
        _section("DMA protocols (Fig.6 / Table 2)", bench_dma.HEADER,
                 bench_dma.run())
        _section("Graph launch scaling (Fig.7/9/10)", bench_graphs.HEADER,
                 bench_graphs.run(session=sess))
        _section("Submission stage split (§6.2/§7)", bench_submission.HEADER,
                 bench_submission.run(session=sess))
        _section("Kernel interpret-mode timings", "name,ms",
                 bench_kernels_rows())
    print("# === Unified trace session ===")
    print(json.dumps(sess.summary(), indent=2, sort_keys=True))


if __name__ == "__main__":
    main()
