"""Paper Figures 7/9/10 analogue: graph-launch scaling across launch modes.

Chain lengths sweep 1→2000 (paper's range).  Per (mode, K):
  * launch time (µs)  — Fig. 7a/b
  * command bytes     — Fig. 7c/d (footprint)
  * doorbell writes   — Fig. 7e/f
  * fitted command-emission bandwidth (MiB/s) — Fig. 9's slope

Launches report ``graph_launch`` (and per-op ``dispatch``) events into the
session passed by the harness — or the ambient one — so the footprint law is
visible on the same timeline as the DMA and trainer sections.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from repro.core import ExecGraph, TraceSession

CHAINS_SHORT = [1, 10, 25, 50, 100, 200]
CHAINS_LONG = [500, 1000, 2000]
MODES = ("per_op", "graphed", "multistep")


def run(width: int = 4096, quick: bool = False,
        session: Optional[TraceSession] = None) -> List[str]:
    rows: List[str] = []
    fits = {m: ([], []) for m in MODES}
    chains = [1, 10, 50, 100] if quick else CHAINS_SHORT + CHAINS_LONG
    for K in chains:
        for mode in MODES:
            if mode == "per_op" and K > 500:
                continue  # python-loop dispatch at K=2000 adds no information
            g = ExecGraph(chain_len=K, width=width)
            g.upload(mode)
            _, st = g.launch(mode, session=session)       # warm
            _, st = g.launch(mode, session=session)
            rows.append(
                f"graph_{mode},{K},{st.launch_s*1e6:.1f},"
                f"{st.command_bytes},{st.doorbells},{st.upload_s*1e3:.1f}")
            fits[mode][0].append(st.command_bytes)
            fits[mode][1].append(st.launch_s)
    for mode in MODES:
        b, t = np.asarray(fits[mode][0], float), np.asarray(fits[mode][1], float)
        if len(b) > 2 and b.std() > 0:
            slope = np.polyfit(b, t, 1)[0]          # s per byte
            bw = 1.0 / max(slope, 1e-12) / 2**20    # MiB/s
            rows.append(f"graph_fit_{mode},,,{bw:.1f},,")
    return rows


HEADER = "name,chain_len,launch_us,command_bytes_or_bw,doorbells,upload_ms"
