"""Continuous-batching serve under replayed Poisson traffic.

The paper's CUDA-Graphs case study says launch overhead dominates exactly
where serving lives: many tiny decode submissions.  This section replays a
seeded Poisson arrival schedule (mixed prompt/output lengths) through the
:class:`~repro.runtime.server.ContinuousBatchingServer` at several
``tokens_per_launch`` settings and reports per-request latency percentiles,
token throughput, and tokens-per-doorbell — the serving-scale trajectory
later PRs measure themselves against (``python -m repro.launch.loadtest``
is the interactive version).

Replay is synchronous (submit-then-drain) so rows are deterministic per
seed; the realtime producer-thread path is exercised by the test suite.
"""
from __future__ import annotations

from typing import List, Optional

from repro.core import TraceSession

HEADER = ("mode,requests,completed,evicted,rejected,new_tokens,doorbells,"
          "tok_per_doorbell,tok_per_s,p50_ms,p99_ms,ttft_p50_ms")


def run(arch: str = "gemma-2b", quick: bool = False,
        session: Optional[TraceSession] = None) -> List[str]:
    from repro.configs import SMOKE_ARCHS
    from repro.runtime.server import ContinuousBatchingServer
    from repro.runtime.traffic import TrafficSpec, generate, replay

    cfg = SMOKE_ARCHS[arch]
    n = 8 if quick else 32
    launches = (1, 4) if quick else (1, 4, 8)
    spec = TrafficSpec(n_requests=n, rate=200.0, prompt_lens=(4, 8),
                       new_tokens=(5, 9), seed=0)
    rows: List[str] = []
    for tpl in launches:
        eng = ContinuousBatchingServer(
            cfg, batch_size=4, max_seq=64, tokens_per_launch=tpl,
            seed=0, session=session)
        # warm replay compiles prefill/decode; the measured replay below is
        # the steady-state serving regime a policy actually runs in
        replay(eng, generate(spec, cfg.vocab_size), realtime=False)
        _, m = replay(eng, generate(spec, cfg.vocab_size), realtime=False)
        rows.append(
            f"cb_T{tpl},{m['requests']},{m['completed']},{m['evicted']},"
            f"{m['rejected']},{m['new_tokens']},{m['doorbells']},"
            f"{m['tokens_per_doorbell']:.2f},{m['tokens_per_s']:.1f},"
            f"{m['latency_p50_s'] * 1e3:.1f},{m['latency_p99_s'] * 1e3:.1f},"
            f"{m['ttft_p50_s'] * 1e3:.1f}")
    return rows
