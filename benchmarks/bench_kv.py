"""Dense vs paged KV backends under shared-prefix serving traffic.

The serving memory path is where the paper's small-submission regime meets
capacity management: the paged backend trades the dense per-slot KV arena
for fixed-size pages with per-slot block tables, which lets requests that
share a prompt prefix share the pages holding it.  This section replays
the same seeded shared-prefix workload (every prompt opens with the same
24 tokens — system-prompt traffic) through both backends with chunked
prefill and reports the command-stream footprint: prefill doorbells,
prefill payload bytes, page-pool occupancy, and prefix-hit reuse.

The workload size is FIXED regardless of ``--quick`` so the trajectory
gate can diff these rows between the committed full baseline and the
quick CI candidate — the count metrics here (doorbells, payload bytes,
pages, prefix hits) are deterministic per seed and gate hard via
``--gate-counts``; mismatched sizes would make the row keys disjoint and
silently ungate the section.
"""
from __future__ import annotations

from typing import List, Optional

from repro.core import TraceSession

HEADER = ("mode,requests,new_tokens,prefill_doorbells,"
          "prefill_payload_bytes,pages_allocated,pages_peak,pages_reused,"
          "prefix_hits,doorbells,tok_per_doorbell")


def run(arch: str = "gemma-2b", quick: bool = False,
        session: Optional[TraceSession] = None) -> List[str]:
    from repro.configs import SMOKE_ARCHS
    from repro.runtime.server import ContinuousBatchingServer
    from repro.runtime.traffic import TrafficSpec, generate, replay

    cfg = SMOKE_ARCHS[arch]
    # fixed size in quick AND full: see module docstring
    spec = TrafficSpec(n_requests=8, rate=1000.0, prompt_lens=(4, 8),
                       new_tokens=(5, 9), seed=0, prefix_len=24)
    modes = (
        ("dense_chunk8", dict(kv="dense", prefill_chunk=8)),
        ("paged_pt8_chunk8", dict(kv="paged", kv_page_tokens=8,
                                  prefill_chunk=8)),
    )
    rows: List[str] = []
    for mode, kw in modes:
        eng = ContinuousBatchingServer(
            cfg, batch_size=4, max_seq=64, tokens_per_launch=4,
            seed=0, session=session, **kw)
        _, m = replay(eng, generate(spec, cfg.vocab_size), realtime=False)
        kv = m["kv"]
        rows.append(
            f"{mode},{m['requests']},{m['new_tokens']},"
            f"{kv['prefill_launches']},{kv['prefill_payload_bytes']},"
            f"{kv.get('pages_allocated', 0)},{kv.get('pages_peak', 0)},"
            f"{kv.get('pages_reused', 0)},{kv.get('prefix_hits', 0)},"
            f"{m['doorbells']},{m['tokens_per_doorbell']:.2f}")
    return rows
