"""Submission-cost stage decomposition (paper §6.2/§7).

Splits one end-to-end jitted call into the stages the paper wants
attributable: trace+lower (driver translate), compile (instantiate),
dispatch (doorbell), execute (engine).  Also measures the Trainer's
multi-step launch economy: host µs per train step vs steps-per-dispatch K.

Both halves report through ONE :class:`repro.core.TraceSession`: the stage
split goes through ``session.capture`` / ``session.wrap`` and the trainers
are constructed with ``session=`` — so compile, dispatch, and progress events
from all of them interleave on a single submission-ordered timeline.
"""
from __future__ import annotations

import time
from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.configs import SMOKE_ARCHS
from repro.configs.shapes import ShapeConfig
from repro.core import TraceSession
from repro.runtime.trainer import Trainer


def _stage_split(width: int = 1024,
                 session: Optional[TraceSession] = None) -> List[str]:
    sess = session or TraceSession(name="stage_split")
    W = jnp.zeros((width, width), jnp.float32)

    def f(x):
        return jnp.tanh(x @ W).sum()

    x = jnp.ones((8, width))
    cs = sess.capture.lower_and_compile("stage_split", f, args=(x,))
    compiled = cs.compiled
    t2 = time.perf_counter()
    out = compiled(x)                     # dispatch (async)
    t3 = time.perf_counter()
    jax.block_until_ready(out)
    t4 = time.perf_counter()
    # steady-state dispatch, doorbell-wrapped onto the shared timeline
    steady = sess.wrap(compiled, "stage_steady_call", block=True)
    times = []
    for _ in range(20):
        s = time.perf_counter()
        steady(x)
        times.append(time.perf_counter() - s)
    times.sort()
    return [
        f"stage_trace_lower,,{cs.lower_time_s*1e6:.1f},,,",
        f"stage_compile,,{cs.compile_time_s*1e6:.1f},,,",
        f"stage_first_dispatch,,{(t3-t2)*1e6:.1f},,,",
        f"stage_first_complete,,{(t4-t3)*1e6:.1f},,,",
        f"stage_steady_call,,{times[len(times)//2]*1e6:.1f},,,",
    ]


def _multistep_economy(quick: bool = False,
                       session: Optional[TraceSession] = None) -> List[str]:
    rows = []
    cfg = SMOKE_ARCHS["deepseek-7b"]
    shape = ShapeConfig("bench", 64, 4, "train")
    for k in ((1, 4) if quick else (1, 4, 16)):
        tr = Trainer(cfg, shape, steps_per_launch=k, seed=0,
                     session=session)
        out = tr.train(8 if quick else 16)
        rows.append(
            f"trainer_k{k},{out['steps']},"
            f"{out['wall_s']/out['steps']*1e6:.1f},"
            f"{out['doorbells']},{out['steps_per_doorbell']:.1f},"
            f"{out['final_loss']:.4f}")
    return rows


def run(quick: bool = False,
        session: Optional[TraceSession] = None) -> List[str]:
    return (_stage_split(session=session)
            + _multistep_economy(quick=quick, session=session))


HEADER = "name,steps,us_per_step,doorbells,steps_per_doorbell,final_loss"
