"""Submission-cost stage decomposition (paper §6.2/§7).

Splits one end-to-end jitted call into the stages the paper wants
attributable: trace+lower (driver translate), compile (instantiate),
dispatch (doorbell), execute (engine).  Also measures the Trainer's
multi-step launch economy: host µs per train step vs steps-per-dispatch K.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.configs import SMOKE_ARCHS
from repro.configs.shapes import ShapeConfig
from repro.runtime.trainer import Trainer


def _stage_split(width: int = 1024) -> List[str]:
    W = jnp.zeros((width, width), jnp.float32)

    def f(x):
        return jnp.tanh(x @ W).sum()

    x = jnp.ones((8, width))
    t0 = time.perf_counter()
    lowered = jax.jit(f).lower(x)
    t1 = time.perf_counter()
    compiled = lowered.compile()
    t2 = time.perf_counter()
    out = compiled(x)                     # dispatch (async)
    t3 = time.perf_counter()
    jax.block_until_ready(out)
    t4 = time.perf_counter()
    # steady-state dispatch
    times = []
    for _ in range(20):
        s = time.perf_counter()
        out = compiled(x)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - s)
    times.sort()
    return [
        f"stage_trace_lower,,{(t1-t0)*1e6:.1f},,,",
        f"stage_compile,,{(t2-t1)*1e6:.1f},,,",
        f"stage_first_dispatch,,{(t3-t2)*1e6:.1f},,,",
        f"stage_first_complete,,{(t4-t3)*1e6:.1f},,,",
        f"stage_steady_call,,{times[len(times)//2]*1e6:.1f},,,",
    ]


def _multistep_economy() -> List[str]:
    rows = []
    cfg = SMOKE_ARCHS["deepseek-7b"]
    shape = ShapeConfig("bench", 64, 4, "train")
    for k in (1, 4, 16):
        tr = Trainer(cfg, shape, steps_per_launch=k, seed=0)
        out = tr.train(16)
        rows.append(
            f"trainer_k{k},{out['steps']},"
            f"{out['wall_s']/out['steps']*1e6:.1f},"
            f"{out['doorbells']},{out['steps_per_doorbell']:.1f},"
            f"{out['final_loss']:.4f}")
    return rows


def run() -> List[str]:
    return _stage_split() + _multistep_economy()


HEADER = "name,steps,us_per_step,doorbells,steps_per_doorbell,final_loss"
